//! Dense row-major f64 matrix — the substrate every Rust-side algorithm
//! (baselines, curve fits, feature extractors) builds on.
//!
//! Deliberately small: this is not a general tensor library, just the exact
//! operations the GRAFT pipeline needs.  The throughput-critical kernels
//! (`matmul`, `gram`, `transpose`, `take_cols`) are register-tiled and
//! cache-blocked, with a `std::thread::scope` row-panel parallel path above
//! [`PAR_MIN_FLOPS`]; the naive reference kernels are kept (`matmul_naive`)
//! for property tests and regression benches.  See `linalg/mod.rs` for the
//! blocking design notes.

use std::fmt;
use std::ops::{Index, IndexMut, Range};
use std::sync::OnceLock;

use super::simd::{axpy2_lanes, axpy_lanes, dot_lanes};

// ---------------------------------------------------------------------------
// Blocking / threading constants (see linalg/mod.rs for the rationale)
// ---------------------------------------------------------------------------

/// Columns of B streamed per panel: one 512-column f64 strip of an output
/// row (4 KiB) plus the matching B strip stays L1-resident.
pub const BLOCK_NC: usize = 512;
/// Inner-dimension block: a `BLOCK_KC × BLOCK_NC` panel of B (≤ 1 MiB)
/// stays L2-resident while every A-row pair streams across it.
pub const BLOCK_KC: usize = 256;
/// Square tile edge for the blocked transpose (32×32 f64 = two 4 KiB
/// pages, well under L1).
pub const BLOCK_TILE: usize = 32;
/// `m·k·n` fused-op count above which `matmul`/`gram` fan row panels out
/// across threads; below it the spawn cost dominates any speedup.
/// Default for [`par_min_flops`], which bench sweeps can override via the
/// `GRAFT_PAR_MIN_FLOPS` env var.
pub const PAR_MIN_FLOPS: usize = 1 << 22;

/// The effective parallel threshold: `GRAFT_PAR_MIN_FLOPS` when set to a
/// parseable `usize` (`0` forces the threaded path, `usize::MAX` pins the
/// serial path — how the CI kernel-parity job exercises both), else
/// [`PAR_MIN_FLOPS`].  Read once per process and latched, so the hot
/// kernels never touch the environment again.
pub fn par_min_flops() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| parse_par_min_flops(std::env::var("GRAFT_PAR_MIN_FLOPS").ok().as_deref()))
}

/// Pure parsing rule behind [`par_min_flops`]: unset or unparseable input
/// (garbage, negative, empty) falls back to the compiled default rather
/// than erroring — a bad sweep variable must never change kernel results,
/// only which path computes them.
fn parse_par_min_flops(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).unwrap_or(PAR_MIN_FLOPS)
}

/// Worker count for the parallel paths: the machine's parallelism, capped
/// by the row count (each worker needs at least one row) and a fleet-
/// friendly ceiling of 8.
fn num_threads(rows: usize) -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8).min(rows.max(1))
}

#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume the matrix and reclaim its backing buffer (capacity
    /// preserved) — lets callers that rebuild matrices every call (the
    /// sharded selection workers) recycle one allocation via
    /// `from_vec`/`into_vec` round-trips.
    #[inline]
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Blocked transpose: 32×32 tiles keep both the source rows and the
    /// destination columns cache-resident, killing the strided-write
    /// penalty of the naive element loop.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        transpose_into(self.rows, self.cols, &self.data, &mut out.data);
        out
    }

    /// Select rows by index (gather).
    pub fn take_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select columns by index (gather), row-streamed: each source row is
    /// touched once and the destination is written sequentially.
    pub fn take_cols(&self, idx: &[usize]) -> Mat {
        let ncols = idx.len();
        let mut out = Mat::zeros(self.rows, ncols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = &mut out.data[i * ncols..(i + 1) * ncols];
            for (d, &j) in dst.iter_mut().zip(idx) {
                *d = src[j];
            }
        }
        out
    }

    /// C = A · B through the blocked kernel (see [`Mat::matmul_into`]).
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// C = A · B written into a caller-owned, pre-shaped output — the
    /// allocation-free entry point.  Register-tiled (two output rows per
    /// pass), cache-blocked (`BLOCK_KC`/`BLOCK_NC`), and parallel over row
    /// panels via `std::thread::scope` once `m·k·n ≥ PAR_MIN_FLOPS`.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(
            self.cols, other.rows,
            "matmul {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        assert_eq!(
            (out.rows, out.cols),
            (self.rows, other.cols),
            "matmul_into output must be {}x{}",
            self.rows, other.cols
        );
        out.data.fill(0.0);
        if self.rows == 0 || self.cols == 0 || other.cols == 0 {
            return;
        }
        let n = other.cols;
        let flops = self.rows * self.cols * n;
        // Probe parallelism (a syscall) only once past the size threshold,
        // so small-matrix loops stay syscall-free.
        let t = if flops >= par_min_flops() { num_threads(self.rows) } else { 1 };
        if t > 1 {
            let rows_per = (self.rows + t - 1) / t;
            std::thread::scope(|s| {
                for (ci, chunk) in out.data.chunks_mut(rows_per * n).enumerate() {
                    let start = ci * rows_per;
                    let end = start + chunk.len() / n;
                    let (a, b) = (&*self, other);
                    s.spawn(move || matmul_panel(a, b, start..end, chunk));
                }
            });
        } else {
            matmul_panel(self, other, 0..self.rows, &mut out.data);
        }
    }

    /// Scalar-reference C = A · B (the pre-blocking ikj loop).  Kept as the
    /// ground truth for kernel property tests and the before/after
    /// regression benches.
    pub fn matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                for j in 0..other.cols {
                    orow[j] += aik * brow[j];
                }
            }
        }
        out
    }

    /// AᵀA Gram matrix (symmetric; only the upper triangle is accumulated,
    /// then mirrored).  Row panels go parallel above `PAR_MIN_FLOPS`
    /// (per-thread partial Grams reduced in thread order — deterministic,
    /// though the summation grouping differs from the serial path by the
    /// usual ~1e-15 float-reassociation noise).
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        // Only the upper triangle is accumulated, so the fused-op count is
        // the symmetric half-work m·n·(n+1)/2 — counting the full m·n·n
        // here made gram go parallel ~2× before the threshold paid off.
        let flops = self.rows * n * (n + 1) / 2;
        let t = if flops >= par_min_flops() { num_threads(self.rows) } else { 1 };
        if t > 1 {
            let rows_per = (self.rows + t - 1) / t;
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(t);
                for ti in 0..t {
                    let start = ti * rows_per;
                    let end = ((ti + 1) * rows_per).min(self.rows);
                    if start >= end {
                        break;
                    }
                    let a = &*self;
                    handles.push(s.spawn(move || {
                        let mut p = Mat::zeros(n, n);
                        gram_upper_panel(a, start..end, &mut p.data);
                        p
                    }));
                }
                for h in handles {
                    g.add_assign(&h.join().expect("gram worker panicked"));
                }
            });
        } else {
            gram_upper_panel(self, 0..self.rows, &mut g.data);
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Scalar-reference AᵀA (pre-blocking loop), kept for property tests.
    pub fn gram_naive(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..n {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = Aᵀ·x.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            axpy_lanes(&mut y, xi, self.row(i));
        }
        y
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Column means.
    pub fn col_mean(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                m[j] += v;
            }
        }
        let inv = 1.0 / self.rows.max(1) as f64;
        for v in &mut m {
            *v *= inv;
        }
        m
    }

    /// Center columns in place; returns the removed means.
    pub fn center_cols(&mut self) -> Vec<f64> {
        let m = self.col_mean();
        for i in 0..self.rows {
            for (j, v) in self.row_mut(i).iter_mut().enumerate() {
                *v -= m[j];
            }
        }
        m
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

// ---------------------------------------------------------------------------
// Kernel bodies (free functions so the threaded paths can borrow panels)
// ---------------------------------------------------------------------------

/// Register-tiled, cache-blocked GEMM panel: `out` is the dense row-major
/// panel for exactly `rows` of the product (pre-zeroed by the caller).
/// Two output rows advance together so every streamed B element is used
/// twice per load; the j/k blocking keeps the active B panel L2-resident.
/// The k-blocks are visited in ascending order, so for finite inputs the
/// per-element summation order matches the naive ikj loop exactly.  (The
/// paired-row zero-skip only elides a k-term when BOTH rows' A-values are
/// zero, so unlike the naive per-row skip a `0.0 * bv` term can execute —
/// identical for finite B, but a non-finite B entry paired with a zero
/// A-value yields NaN here where the naive loop skips it.)
fn matmul_panel(a: &Mat, b: &Mat, rows: Range<usize>, out: &mut [f64]) {
    let n = b.cols;
    let kk = b.rows;
    let nrows = rows.len();
    debug_assert_eq!(out.len(), nrows * n);
    for j0 in (0..n).step_by(BLOCK_NC) {
        let jend = (j0 + BLOCK_NC).min(n);
        for k0 in (0..kk).step_by(BLOCK_KC) {
            let kend = (k0 + BLOCK_KC).min(kk);
            let mut oi = 0;
            while oi + 1 < nrows {
                let i0 = rows.start + oi;
                let (head, tail) = out.split_at_mut((oi + 1) * n);
                let r0 = &mut head[oi * n + j0..oi * n + jend];
                let r1 = &mut tail[j0..jend];
                let a0 = a.row(i0);
                let a1 = a.row(i0 + 1);
                for k in k0..kend {
                    let (x0, x1) = (a0[k], a1[k]);
                    if x0 == 0.0 && x1 == 0.0 {
                        continue;
                    }
                    let brow = &b.row(k)[j0..jend];
                    axpy2_lanes(r0, r1, x0, x1, brow);
                }
                oi += 2;
            }
            if oi < nrows {
                let arow = a.row(rows.start + oi);
                let orow = &mut out[oi * n + j0..oi * n + jend];
                for k in k0..kend {
                    let aik = arow[k];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.row(k)[j0..jend];
                    axpy_lanes(orow, aik, brow);
                }
            }
        }
    }
}

/// Upper-triangle Gram accumulation over a row panel of A: `g` is a dense
/// n×n buffer; only entries `j ≥ i` are touched.  The inner loop runs over
/// contiguous row suffixes so it vectorises.
fn gram_upper_panel(a: &Mat, rows: Range<usize>, g: &mut [f64]) {
    let n = a.cols;
    for r in rows {
        let row = a.row(r);
        for (i, &ri) in row.iter().enumerate() {
            if ri == 0.0 {
                continue;
            }
            let gi = &mut g[i * n + i..(i + 1) * n];
            axpy_lanes(gi, ri, &row[i..]);
        }
    }
}

/// Tiled out-of-place transpose of a `rows×cols` row-major buffer.
///
/// The allocation-free twin of [`Mat::transpose`]: callers that already
/// hold scratch (a [`super::Workspace`] arena, a retained `Vec<f64>`)
/// write into it directly instead of allocating a fresh `Mat` per call
/// (covered by `tests/alloc_free.rs`).
pub fn transpose_into(rows: usize, cols: usize, src: &[f64], dst: &mut [f64]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for i0 in (0..rows).step_by(BLOCK_TILE) {
        let iend = (i0 + BLOCK_TILE).min(rows);
        for j0 in (0..cols).step_by(BLOCK_TILE) {
            let jend = (j0 + BLOCK_TILE).min(cols);
            for i in i0..iend {
                let row = &src[i * cols..(i + 1) * cols];
                for j in j0..jend {
                    dst[j * rows + i] = row[j];
                }
            }
        }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

// -------------------------------------------------------------------------
// Vector helpers (shared across the crate)
// -------------------------------------------------------------------------

/// Dot product through the 4-lane kernel (see [`super::simd::dot_lanes`]
/// for the deterministic-but-reassociated summation contract).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    dot_lanes(a, b)
}

#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += α·x` through the 4-lane kernel (bit-exact vs. the scalar loop).
pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    axpy_lanes(y, alpha, x);
}

pub fn normalize(v: &mut [f64]) -> f64 {
    let n = norm2(v);
    if n > 1e-300 {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_identity() {
        let a = randmat(5, 7, 1);
        let i = Mat::eye(7);
        let prod = a.matmul(&i);
        assert!((prod.sub(&a)).max_abs() < 1e-12);
    }

    #[test]
    fn matmul_associative() {
        let a = randmat(4, 5, 2);
        let b = randmat(5, 6, 3);
        let c = randmat(6, 3, 4);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.sub(&right).max_abs() < 1e-10);
    }

    #[test]
    fn matmul_matches_naive() {
        for (m, k, n, seed) in [(5, 7, 3, 1), (33, 17, 65, 2), (2, 600, 2, 3), (1, 1, 1, 4)] {
            let a = randmat(m, k, seed);
            let b = randmat(k, n, seed + 100);
            assert!(
                a.matmul(&b).sub(&a.matmul_naive(&b)).max_abs() < 1e-12,
                "blocked != naive at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn matmul_into_reuses_buffer() {
        let a = randmat(6, 5, 5);
        let b = randmat(5, 4, 6);
        let mut out = Mat::zeros(6, 4);
        a.matmul_into(&b, &mut out);
        assert!(out.sub(&a.matmul_naive(&b)).max_abs() < 1e-12);
        // Second call overwrites (not accumulates).
        a.matmul_into(&b, &mut out);
        assert!(out.sub(&a.matmul_naive(&b)).max_abs() < 1e-12);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = randmat(9, 4, 5);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g.sub(&g2).max_abs() < 1e-10);
        assert!(g.sub(&a.gram_naive()).max_abs() < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let a = randmat(6, 3, 6);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_tiled_matches_fn() {
        let a = randmat(70, 41, 12);
        let t = a.transpose();
        for i in 0..70 {
            for j in 0..41 {
                assert_eq!(t[(j, i)], a[(i, j)]);
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = randmat(5, 4, 7);
        let x: Vec<f64> = (0..4).map(|i| i as f64 + 0.5).collect();
        let xm = Mat::from_vec(4, 1, x.clone());
        let want = a.matmul(&xm);
        let got = a.matvec(&x);
        for i in 0..5 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn tmatvec_matches_transpose() {
        let a = randmat(5, 4, 8);
        let x: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let want = a.transpose().matvec(&x);
        let got = a.tmatvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn center_cols_zero_mean() {
        let mut a = randmat(20, 5, 9);
        a.center_cols();
        for m in a.col_mean() {
            assert!(m.abs() < 1e-12);
        }
    }

    #[test]
    fn take_rows_cols() {
        let a = randmat(6, 6, 10);
        let sub = a.take_rows(&[1, 3]).take_cols(&[0, 5]);
        assert_eq!(sub[(0, 0)], a[(1, 0)]);
        assert_eq!(sub[(1, 1)], a[(3, 5)]);
    }

    #[test]
    fn empty_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 0);
        assert_eq!(a.matmul(&randmat(5, 3, 14)).rows(), 0);
        assert_eq!(randmat(3, 5, 15).matmul(&b).cols(), 0);
        assert_eq!(b.gram().rows(), 0);
        assert_eq!(a.transpose().rows(), 5);
    }

    #[test]
    fn par_min_flops_parse_falls_back_on_garbage() {
        // Unset and unparseable values (garbage, negative, empty,
        // whitespace) all fall back to the compiled default; valid values
        // win, including the 0 / usize::MAX extremes the CI kernel-parity
        // job uses to force each path.
        assert_eq!(parse_par_min_flops(None), PAR_MIN_FLOPS);
        for bad in ["garbage", "-5", "", "  ", "1.5", "0x10", "1e6"] {
            assert_eq!(parse_par_min_flops(Some(bad)), PAR_MIN_FLOPS, "input {bad:?}");
        }
        assert_eq!(parse_par_min_flops(Some("0")), 0);
        assert_eq!(parse_par_min_flops(Some(" 4096 ")), 4096);
        assert_eq!(
            parse_par_min_flops(Some("18446744073709551615")),
            usize::MAX,
            "usize::MAX round-trips"
        );
    }

    #[test]
    fn f32_roundtrip() {
        let a = randmat(3, 3, 11);
        let b = Mat::from_f32(3, 3, &a.to_f32());
        assert!(a.sub(&b).max_abs() < 1e-6);
    }
}

//! Dense row-major f64 matrix — the substrate every Rust-side algorithm
//! (baselines, curve fits, feature extractors) builds on.
//!
//! Deliberately small: this is not a general tensor library, just the exact
//! operations the GRAFT pipeline needs, written so the per-step hot loops
//! (MaxVol rank-1 updates, Gram accumulation) stay allocation-free.

use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Select rows by index (gather).
    pub fn take_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select columns by index (gather).
    pub fn take_cols(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(self.rows, idx.len(), |i, j| self[(i, idx[j])])
    }

    /// C = A · B (ikj loop order — cache-friendly row-major).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                for j in 0..other.cols {
                    orow[j] += aik * brow[j];
                }
            }
        }
        out
    }

    /// AᵀA Gram matrix (symmetric; only one triangle computed then mirrored).
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..n {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = Aᵀ·x.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (j, &a) in self.row(i).iter().enumerate() {
                y[j] += xi * a;
            }
        }
        y
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Column means.
    pub fn col_mean(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                m[j] += v;
            }
        }
        let inv = 1.0 / self.rows.max(1) as f64;
        for v in &mut m {
            *v *= inv;
        }
        m
    }

    /// Center columns in place; returns the removed means.
    pub fn center_cols(&mut self) -> Vec<f64> {
        let m = self.col_mean();
        for i in 0..self.rows {
            for (j, v) in self.row_mut(i).iter_mut().enumerate() {
                *v -= m[j];
            }
        }
        m
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

// -------------------------------------------------------------------------
// Vector helpers (shared across the crate)
// -------------------------------------------------------------------------

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn normalize(v: &mut [f64]) -> f64 {
    let n = norm2(v);
    if n > 1e-300 {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_identity() {
        let a = randmat(5, 7, 1);
        let i = Mat::eye(7);
        let prod = a.matmul(&i);
        assert!((prod.sub(&a)).max_abs() < 1e-12);
    }

    #[test]
    fn matmul_associative() {
        let a = randmat(4, 5, 2);
        let b = randmat(5, 6, 3);
        let c = randmat(6, 3, 4);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.sub(&right).max_abs() < 1e-10);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = randmat(9, 4, 5);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g.sub(&g2).max_abs() < 1e-10);
    }

    #[test]
    fn transpose_involution() {
        let a = randmat(6, 3, 6);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = randmat(5, 4, 7);
        let x: Vec<f64> = (0..4).map(|i| i as f64 + 0.5).collect();
        let xm = Mat::from_vec(4, 1, x.clone());
        let want = a.matmul(&xm);
        let got = a.matvec(&x);
        for i in 0..5 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn tmatvec_matches_transpose() {
        let a = randmat(5, 4, 8);
        let x: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let want = a.transpose().matvec(&x);
        let got = a.tmatvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn center_cols_zero_mean() {
        let mut a = randmat(20, 5, 9);
        a.center_cols();
        for m in a.col_mean() {
            assert!(m.abs() < 1e-12);
        }
    }

    #[test]
    fn take_rows_cols() {
        let a = randmat(6, 6, 10);
        let sub = a.take_rows(&[1, 3]).take_cols(&[0, 5]);
        assert_eq!(sub[(0, 0)], a[(1, 0)]);
        assert_eq!(sub[(1, 1)], a[(3, 5)]);
    }

    #[test]
    fn f32_roundtrip() {
        let a = randmat(3, 3, 11);
        let b = Mat::from_f32(3, 3, &a.to_f32());
        assert!(a.sub(&b).max_abs() < 1e-6);
    }
}

//! Exponential gain-curve fitting (paper §4, Fig 3): fit
//!
//!   E(x) = E₀ + (H − E₀)(1 − e^{−λ x / x_max})
//!
//! to (resource, performance) points by Gauss-Newton with Levenberg
//! damping, and report (E₀, H, λ, R²) per method/dataset — the numbers
//! behind the paper's "λ values 1.8–2.4× higher than competing methods".

/// Fitted parameters + goodness of fit.
#[derive(Debug, Clone, Copy)]
pub struct GainFit {
    pub e0: f64,
    pub h: f64,
    pub lambda: f64,
    pub r2: f64,
}

fn model(e0: f64, h: f64, lambda: f64, xnorm: f64) -> f64 {
    e0 + (h - e0) * (1.0 - (-lambda * xnorm).exp())
}

/// Fit the exponential gain curve to (x, y) points. `x_max` normalises x.
/// Returns None for degenerate inputs (<3 points or zero variance).
pub fn fit_gain_curve(xs: &[f64], ys: &[f64]) -> Option<GainFit> {
    let n = xs.len();
    if n < 3 || n != ys.len() {
        return None;
    }
    let x_max = xs.iter().cloned().fold(f64::MIN, f64::max);
    if !(x_max > 0.0) {
        return None;
    }
    let ymean = ys.iter().sum::<f64>() / n as f64;
    let sst: f64 = ys.iter().map(|y| (y - ymean) * (y - ymean)).sum();
    if sst <= 0.0 {
        return None;
    }

    // Initialisation: E₀ = y at smallest x, H = max y, λ = 2.
    let (mut e0, mut h, mut lambda) = {
        let i_min = (0..n).min_by(|&a, &b| xs[a].total_cmp(&xs[b]))?;
        let ymax = ys.iter().cloned().fold(f64::MIN, f64::max);
        (ys[i_min].min(ymax - 1e-6), ymax, 2.0f64)
    };
    let mut mu = 1e-3; // Levenberg damping
    let mut last_sse = f64::MAX;
    for _ in 0..200 {
        // Residuals + Jacobian (3 columns: ∂E₀, ∂H, ∂λ).
        let mut jtj = [[0.0f64; 3]; 3];
        let mut jtr = [0.0f64; 3];
        let mut sse = 0.0;
        for i in 0..n {
            let xn = xs[i] / x_max;
            let ex = (-lambda * xn).exp();
            let pred = model(e0, h, lambda, xn);
            let r = ys[i] - pred;
            sse += r * r;
            let j = [ex, 1.0 - ex, (h - e0) * xn * ex];
            for a in 0..3 {
                jtr[a] += j[a] * r;
                for b in 0..3 {
                    jtj[a][b] += j[a] * j[b];
                }
            }
        }
        if (last_sse - sse).abs() < 1e-14 {
            break;
        }
        last_sse = sse;
        // Solve (JᵀJ + μI) δ = Jᵀr.
        let mut a = jtj;
        for t in 0..3 {
            a[t][t] += mu * (1.0 + jtj[t][t]);
        }
        let delta = solve3(&a, &jtr)?;
        let (ne0, nh, nl) = (e0 + delta[0], h + delta[1], (lambda + delta[2]).clamp(1e-3, 50.0));
        // Accept if SSE improves, else increase damping.
        let new_sse: f64 = (0..n)
            .map(|i| {
                let r = ys[i] - model(ne0, nh, nl, xs[i] / x_max);
                r * r
            })
            .sum();
        if new_sse < sse {
            e0 = ne0;
            h = nh;
            lambda = nl;
            mu = (mu * 0.5).max(1e-12);
        } else {
            mu *= 4.0;
            if mu > 1e8 {
                break;
            }
        }
    }
    let sse: f64 = (0..n)
        .map(|i| {
            let r = ys[i] - model(e0, h, lambda, xs[i] / x_max);
            r * r
        })
        .sum();
    Some(GainFit { e0, h, lambda, r2: 1.0 - sse / sst })
}

fn solve3(a: &[[f64; 3]; 3], b: &[f64; 3]) -> Option<[f64; 3]> {
    let m = crate::linalg::Mat::from_vec(3, 3, a.iter().flatten().copied().collect());
    let x = crate::linalg::lu_solve(&m, b)?;
    Some([x[0], x[1], x[2]])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_known_parameters() {
        let (e0, h, lambda) = (0.2, 0.9, 3.0);
        let xs: Vec<f64> = (1..=10).map(|i| i as f64 * 0.01).collect();
        let xmax = 0.1;
        let ys: Vec<f64> = xs.iter().map(|&x| model(e0, h, lambda, x / xmax)).collect();
        let fit = fit_gain_curve(&xs, &ys).unwrap();
        assert!((fit.e0 - e0).abs() < 1e-4, "{fit:?}");
        assert!((fit.h - h).abs() < 1e-4);
        assert!((fit.lambda - lambda).abs() < 1e-2);
        assert!(fit.r2 > 0.999999);
    }

    #[test]
    fn noisy_fit_reasonable() {
        use crate::rng::Rng;
        let mut rng = Rng::new(3);
        let (e0, h, lambda) = (0.1, 0.85, 2.5);
        let xs: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| model(e0, h, lambda, x / 20.0) + 0.01 * rng.normal())
            .collect();
        let fit = fit_gain_curve(&xs, &ys).unwrap();
        assert!((fit.lambda - lambda).abs() < 0.8, "{fit:?}");
        assert!(fit.r2 > 0.95);
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(fit_gain_curve(&[1.0, 2.0], &[0.1, 0.2]).is_none());
        assert!(fit_gain_curve(&[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5]).is_none());
    }

    #[test]
    fn faster_gain_higher_lambda() {
        // The discriminative use in Fig 3: steeper curves → larger λ.
        let xs: Vec<f64> = (1..=12).map(|i| i as f64).collect();
        let fast: Vec<f64> = xs.iter().map(|&x| model(0.1, 0.9, 4.0, x / 12.0)).collect();
        let slow: Vec<f64> = xs.iter().map(|&x| model(0.1, 0.9, 1.2, x / 12.0)).collect();
        let ff = fit_gain_curve(&xs, &fast).unwrap();
        let fs = fit_gain_curve(&xs, &slow).unwrap();
        assert!(ff.lambda > 2.0 * fs.lambda, "{} vs {}", ff.lambda, fs.lambda);
    }
}

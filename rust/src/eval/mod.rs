//! Evaluation substrate: curve fitting for Fig 3 and report rendering for
//! every table harness.

pub mod fit;
pub mod report;

pub use fit::{fit_gain_curve, GainFit};
pub use report::{save_result, Table};

//! Report rendering: ASCII tables (paper-table layout) + CSV files under
//! `results/`.  Every experiment harness funnels its numbers through here
//! so EXPERIMENTS.md rows are copy-pasteable from run output.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple column-aligned ASCII table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String| {
            for (i, w) in widths.iter().enumerate() {
                out.push_str(if i == 0 { "+" } else { "+" });
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        line(&mut out);
        for (i, h) in self.headers.iter().enumerate() {
            let _ = write!(out, "| {:width$} ", h, width = widths[i]);
        }
        out.push_str("|\n");
        line(&mut out);
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                let _ = write!(out, "| {:width$} ", c, width = widths[i]);
            }
            out.push_str("|\n");
        }
        line(&mut out);
        let _ = ncol;
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a string to `results/<name>` (creating the directory).
pub fn save_result(name: &str, contents: &str) -> Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, contents)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["method", "acc"]);
        t.row(vec!["graft".into(), "0.91".into()]);
        t.row(vec!["gradmatch-long".into(), "0.89".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("| graft"));
        // All data lines equal length.
        let lens: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}

//! Mini-batch loader: seeded shuffling, fixed batch size K (matching the
//! AOT artifact shapes), epoch accounting.  The last partial batch of an
//! epoch is dropped (standard practice; the artifacts need exactly K rows).

use super::Dataset;
use crate::rng::Rng;

/// Deterministic epoch-based batcher over row indices.
pub struct Batcher {
    n: usize,
    k: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(dataset: &Dataset, k: usize, seed: u64) -> Self {
        assert!(k <= dataset.n, "batch {} > dataset {}", k, dataset.n);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..dataset.n).collect();
        rng.shuffle(&mut order);
        Batcher { n: dataset.n, k, order, cursor: 0, epoch: 0, rng }
    }

    pub fn batch_size(&self) -> usize {
        self.k
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.k
    }

    /// Next batch of K row indices; reshuffles at epoch boundaries.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.k > self.n {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let b = &self.order[self.cursor..self.cursor + self.k];
        self.cursor += self.k;
        b
    }

    /// Iterate the test set in fixed-size windows, padding the tail by
    /// wrapping (callers subtract the overlap from counts via `valid`).
    pub fn eval_windows(n: usize, k: usize) -> Vec<(Vec<usize>, usize)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let valid = k.min(n - i);
            let mut idx: Vec<usize> = (i..i + valid).collect();
            while idx.len() < k {
                idx.push(idx.len() % n); // wrap-pad
            }
            out.push((idx, valid));
            i += k;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize) -> Dataset {
        Dataset::new("t", vec![0.0; n * 2], vec![0; n], 2, 1)
    }

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let d = ds(100);
        let mut b = Batcher::new(&d, 32, 1);
        let mut seen = Vec::new();
        for _ in 0..b.batches_per_epoch() {
            seen.extend_from_slice(b.next_batch());
        }
        let mut s = seen.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), seen.len()); // no repeats within an epoch
        assert_eq!(seen.len(), 96); // 3 full batches of 32
    }

    #[test]
    fn epoch_increments_and_reshuffles() {
        let d = ds(64);
        let mut b = Batcher::new(&d, 32, 2);
        let first: Vec<usize> = b.next_batch().to_vec();
        b.next_batch();
        assert_eq!(b.epoch(), 0);
        let third: Vec<usize> = b.next_batch().to_vec();
        assert_eq!(b.epoch(), 1);
        assert_ne!(first, third); // reshuffled (w.h.p.)
    }

    #[test]
    fn deterministic_given_seed() {
        let d = ds(50);
        let mut a = Batcher::new(&d, 16, 3);
        let mut b = Batcher::new(&d, 16, 3);
        for _ in 0..10 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn eval_windows_cover_all() {
        let ws = Batcher::eval_windows(10, 4);
        assert_eq!(ws.len(), 3);
        assert_eq!(ws[2].1, 2); // last window has 2 valid rows
        let covered: usize = ws.iter().map(|(_, v)| v).sum();
        assert_eq!(covered, 10);
        assert!(ws.iter().all(|(idx, _)| idx.len() == 4));
    }
}

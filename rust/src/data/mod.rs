//! Dataset substrate: in-memory datasets, synthetic families standing in
//! for the paper's benchmarks (DESIGN.md §2), and the mini-batch loader.

pub mod corpus;
pub mod iris;
pub mod loader;
pub mod synth;

pub use loader::Batcher;
pub use synth::{synth_dataset, SynthSpec};

/// An in-memory classification dataset: row-major f32 features + labels.
/// f32 because this is the exact layout fed to the PJRT executables.
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    /// n × d, row-major.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn new(name: &str, x: Vec<f32>, y: Vec<i32>, d: usize, classes: usize) -> Self {
        assert_eq!(x.len() % d, 0);
        let n = x.len() / d;
        assert_eq!(y.len(), n);
        debug_assert!(y.iter().all(|&c| (c as usize) < classes));
        Dataset { name: name.into(), x, y, n, d, classes }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// One-hot encode labels for a set of rows (k × classes, row-major).
    pub fn one_hot(&self, rows: &[usize]) -> Vec<f32> {
        let mut out = vec![0.0; rows.len() * self.classes];
        for (k, &i) in rows.iter().enumerate() {
            out[k * self.classes + self.y[i] as usize] = 1.0;
        }
        out
    }

    /// Gather feature rows (k × d, row-major).
    pub fn gather(&self, rows: &[usize]) -> Vec<f32> {
        let mut out = Vec::with_capacity(rows.len() * self.d);
        for &i in rows {
            out.extend_from_slice(self.row(i));
        }
        out
    }

    /// Deterministic train/test split by fraction (stratified per class so
    /// small classes survive the split).
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        use crate::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); self.classes];
        for i in 0..self.n {
            per_class[self.y[i] as usize].push(i);
        }
        let (mut tr, mut te) = (Vec::new(), Vec::new());
        for idxs in per_class.iter_mut() {
            rng.shuffle(idxs);
            let ntr = ((idxs.len() as f64) * train_frac).round() as usize;
            tr.extend_from_slice(&idxs[..ntr]);
            te.extend_from_slice(&idxs[ntr..]);
        }
        rng.shuffle(&mut tr);
        rng.shuffle(&mut te);
        (self.subset("train", &tr), self.subset("test", &te))
    }

    pub fn subset(&self, tag: &str, rows: &[usize]) -> Dataset {
        Dataset::new(
            &format!("{}-{}", self.name, tag),
            self.gather(rows),
            rows.iter().map(|&i| self.y[i]).collect(),
            self.d,
            self.classes,
        )
    }

    /// Z-score every feature column in place (mean 0, std 1).
    pub fn standardize(&mut self) {
        for j in 0..self.d {
            let mut mean = 0.0f64;
            for i in 0..self.n {
                mean += self.x[i * self.d + j] as f64;
            }
            mean /= self.n.max(1) as f64;
            let mut var = 0.0f64;
            for i in 0..self.n {
                let v = self.x[i * self.d + j] as f64 - mean;
                var += v * v;
            }
            let std = (var / self.n.max(1) as f64).sqrt().max(1e-6);
            for i in 0..self.n {
                let v = &mut self.x[i * self.d + j];
                *v = ((*v as f64 - mean) / std) as f32;
            }
        }
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0; self.classes];
        for &y in &self.y {
            c[y as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = (0..20).map(|i| i as f32).collect();
        let y = vec![0, 1, 0, 1, 0, 1, 0, 1, 0, 1];
        Dataset::new("tiny", x, y, 2, 2)
    }

    #[test]
    fn one_hot_layout() {
        let d = tiny();
        let oh = d.one_hot(&[0, 1]);
        assert_eq!(oh, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn gather_rows() {
        let d = tiny();
        assert_eq!(d.gather(&[2, 0]), vec![4.0, 5.0, 0.0, 1.0]);
    }

    #[test]
    fn split_partitions() {
        let d = tiny();
        let (tr, te) = d.split(0.8, 1);
        assert_eq!(tr.n + te.n, d.n);
        assert_eq!(tr.d, 2);
        // Stratified: both classes in train.
        assert!(tr.class_counts().iter().all(|&c| c > 0));
    }

    #[test]
    fn split_deterministic() {
        let d = tiny();
        let (a, _) = d.split(0.5, 7);
        let (b, _) = d.split(0.5, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}

//! Synthetic dataset family standing in for the paper's image benchmarks.
//!
//! Each class c lives on its own low-rank affine subspace: a class mean
//! μ_c plus a per-class basis B_c ∈ R^{d×r_intra} with Gaussian loadings,
//! plus isotropic noise and a fraction of near-duplicate samples.  This
//! gives the two properties subset selection dynamics depend on
//! (DESIGN.md §2): dominant low-rank structure for the feature extractor
//! to find, and intra-class redundancy for MaxVol to exploit — a diverse
//! R-subset genuinely carries most of the batch's information.

use super::Dataset;
use crate::rng::Rng;

/// Specification of one synthetic classification dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
    /// Intra-class subspace rank.
    pub intra_rank: usize,
    /// Sub-clusters (modes) per class: classes are multi-modal mosaics, so
    /// small fractions under-cover the modes and accuracy genuinely rises
    /// with data — the sample-complexity axis of Fig 3 / Tables 8-14.
    pub modes: usize,
    /// Class-mean separation (signal strength).
    pub separation: f64,
    /// Isotropic noise σ.
    pub noise: f64,
    /// Fraction of samples that are near-duplicates of another sample in
    /// the same class (redundancy the sampler can prune "for free").
    pub redundancy: f64,
    /// Fraction of labels flipped uniformly (annotation noise — keeps the
    /// task from being linearly saturated and differentiates selectors).
    pub label_noise: f64,
    /// Class imbalance: 0 = balanced (exactly n/classes rows each, the
    /// legacy generator bit for bit); λ ∈ (0, 1) gives class c a geometric
    /// weight (1 − λ)^c, rounded to the same total n by largest remainder
    /// (every class keeps ≥ 1 row).  Deterministic — no extra RNG draws.
    pub imbalance: f64,
    /// Mid-stream distribution shift: 0 = off; s ∈ (0, 1] translates every
    /// row at stream position ≥ ⌊n·s⌋ by one seeded random direction
    /// (drawn after all legacy draws, so s = 0 is bit-identical).
    pub shift_point: f64,
    /// Curriculum ordering: 0 = shuffled stream order (legacy, bitwise);
    /// c ∈ (0, 1] re-sorts rows by a blend of their shuffled position and
    /// their difficulty rank (distance to own-class centroid) — c = 1 is
    /// pure easy→hard.  A pure permutation: the row multiset is unchanged
    /// and no RNG is drawn.
    pub curriculum: f64,
    pub seed: u64,
}

/// Catalogue matching `python/compile/configs.py` shapes. The n values are
/// laptop-scale stand-ins for the real datasets (DESIGN.md §2); class
/// counts match the originals.
pub fn spec(name: &str) -> Option<SynthSpec> {
    let s = match name {
        "cifar10" => SynthSpec {
            name: "cifar10", n: 12_800, d: 256, classes: 10, intra_rank: 8, modes: 32,
            separation: 1.0, noise: 1.0, redundancy: 0.3, label_noise: 0.01,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 0xC1FA_0010,
        },
        "cifar100" => SynthSpec {
            name: "cifar100", n: 12_800, d: 256, classes: 100, intra_rank: 4, modes: 8,
            separation: 0.9, noise: 1.0, redundancy: 0.25, label_noise: 0.02,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 0xC1FA_0100,
        },
        "fashionmnist" => SynthSpec {
            name: "fashionmnist", n: 12_800, d: 196, classes: 10, intra_rank: 6, modes: 24,
            separation: 1.15, noise: 1.0, redundancy: 0.35, label_noise: 0.01,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 0xFA50_0010,
        },
        "tinyimagenet" => SynthSpec {
            name: "tinyimagenet", n: 12_800, d: 256, classes: 200, intra_rank: 3, modes: 5,
            separation: 0.82, noise: 1.0, redundancy: 0.2, label_noise: 0.02,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 0x7191_0200,
        },
        "caltech256" => SynthSpec {
            name: "caltech256", n: 10_280, d: 256, classes: 257, intra_rank: 3, modes: 4,
            separation: 0.85, noise: 1.0, redundancy: 0.2, label_noise: 0.02,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 0xCA17_0257,
        },
        "dermamnist" => SynthSpec {
            name: "dermamnist", n: 7_000, d: 147, classes: 7, intra_rank: 5, modes: 26,
            separation: 0.9, noise: 1.0, redundancy: 0.3, label_noise: 0.02,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 0xDE3A_0007,
        },
        _ => return None,
    };
    Some(s)
}

pub fn synth_dataset(spec: &SynthSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let d = spec.d;
    // Class means on a random sphere of radius `separation`.
    let sqrt_d = (d as f64).sqrt();
    // Mode means: each class is a mosaic of `modes` sub-clusters.  Modes
    // of *different* classes are interleaved at the same scale, so the
    // decision boundary is locally fine-grained: a training set must cover
    // most modes before accuracy saturates.
    let mode_scale = spec.separation * sqrt_d / 2.0;
    let mut mode_means: Vec<Vec<Vec<f64>>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut ms = Vec::with_capacity(spec.modes);
        for _ in 0..spec.modes.max(1) {
            let mut m = rng.normals(d);
            let n = crate::linalg::norm2(&m);
            let scale = mode_scale / n.max(1e-12) * (1.0 + rng.uniform());
            for v in &mut m {
                *v *= scale;
            }
            ms.push(m);
        }
        mode_means.push(ms);
    }
    // Per-class bases: direction r carries energy ∝ 1/(r+1) with the
    // leading direction comparable to the noise — enough low-rank
    // structure for the extractor to find without swamping the class
    // signal.
    let mut bases: Vec<Vec<Vec<f64>>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut b = Vec::with_capacity(spec.intra_rank);
        for r in 0..spec.intra_rank {
            let mut v = rng.normals(d);
            let n = crate::linalg::norm2(&v);
            let scale = 1.2 * sqrt_d / (n.max(1e-12) * (r as f64 + 1.0));
            for x in &mut v {
                *x *= scale;
            }
            b.push(v);
        }
        bases.push(b);
    }

    let counts = class_counts_for(spec);
    let n: usize = counts.iter().sum();
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0i32; n];
    let mut idx = 0usize;
    for c in 0..spec.classes {
        let mut class_rows: Vec<usize> = Vec::new();
        for _k in 0..counts[c] {
            let dup = !class_rows.is_empty() && rng.uniform() < spec.redundancy;
            let mut row = vec![0.0f64; d];
            if dup {
                let src = class_rows[rng.below(class_rows.len())];
                for t in 0..d {
                    row[t] = x[src * d + t] as f64 + 0.05 * spec.noise * rng.normal();
                }
            } else {
                let mode = rng.below(spec.modes.max(1));
                row.copy_from_slice(&mode_means[c][mode]);
                for b in &bases[c] {
                    let load = rng.normal();
                    for t in 0..d {
                        row[t] += load * b[t];
                    }
                }
                for t in 0..d {
                    row[t] += 0.6 * spec.noise * rng.normal();
                }
            }
            for t in 0..d {
                x[idx * d + t] = row[t] as f32;
            }
            y[idx] = c as i32;
            class_rows.push(idx);
            idx += 1;
        }
    }
    // Normalise features globally to zero mean / unit variance per dim
    // (what image pipelines do), then shuffle rows.
    normalise_cols(&mut x, n, d);
    let perm = rng.permutation(n);
    let mut xs = vec![0.0f32; n * d];
    let mut ys = vec![0i32; n];
    for (new, &old) in perm.iter().enumerate() {
        xs[new * d..(new + 1) * d].copy_from_slice(&x[old * d..(old + 1) * d]);
        ys[new] = y[old];
    }
    if spec.label_noise > 0.0 {
        for yv in ys.iter_mut() {
            if rng.uniform() < spec.label_noise {
                *yv = rng.below(spec.classes) as i32;
            }
        }
    }
    if spec.curriculum > 0.0 {
        curriculum_reorder(&mut xs, &mut ys, n, d, spec.classes, spec.curriculum);
    }
    if spec.shift_point > 0.0 {
        // One seeded direction, drawn after every legacy draw — the RNG
        // stream up to here (and therefore the pre-shift prefix of the
        // dataset) is bit-identical to the shift_point = 0 generator.
        let cut = ((n as f64) * spec.shift_point.min(1.0)).floor() as usize;
        let mut dir = rng.normals(d);
        let scale = (d as f64).sqrt() / crate::linalg::norm2(&dir).max(1e-12);
        for v in &mut dir {
            *v *= scale;
        }
        for i in cut..n {
            for t in 0..d {
                xs[i * d + t] = (xs[i * d + t] as f64 + dir[t]) as f32;
            }
        }
    }
    Dataset::new(spec.name, xs, ys, d, spec.classes)
}

/// Per-class row counts for `spec`: exactly `n / classes` each at
/// `imbalance = 0` (the legacy balanced generator); otherwise geometric
/// weights (1 − λ)^c rounded to the same total by largest remainder, with
/// every class kept ≥ 1 row.  Deterministic — draws no RNG.
pub fn class_counts_for(spec: &SynthSpec) -> Vec<usize> {
    let per_class = spec.n / spec.classes;
    let total = per_class * spec.classes;
    if spec.imbalance <= 0.0 {
        return vec![per_class; spec.classes];
    }
    let lambda = spec.imbalance.min(0.999);
    let weights: Vec<f64> = (0..spec.classes).map(|c| (1.0 - lambda).powi(c as i32)).collect();
    let wsum: f64 = weights.iter().sum();
    let quotas: Vec<f64> = weights.iter().map(|w| total as f64 * w / wsum).collect();
    let mut counts: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let mut have: usize = counts.iter().sum();
    // Largest fractional remainder first (ties → lower class index).
    let mut order: Vec<usize> = (0..spec.classes).collect();
    order.sort_by(|&a, &b| {
        let (fa, fb) = (quotas[a] - quotas[a].floor(), quotas[b] - quotas[b].floor());
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    let mut oi = 0usize;
    while have < total {
        counts[order[oi % spec.classes]] += 1;
        have += 1;
        oi += 1;
    }
    // Tail classes rounded to zero borrow a row from the largest class.
    for c in 0..spec.classes {
        if counts[c] == 0 {
            let big = (0..spec.classes).max_by_key(|&i| counts[i]).unwrap();
            if counts[big] > 1 {
                counts[big] -= 1;
                counts[c] = 1;
            }
        }
    }
    counts
}

/// Stable easy→hard re-sort of the shuffled stream: difficulty is the
/// squared distance to the own-class centroid in the normalised feature
/// space (label-noise rows land far from "their" centroid, i.e. late), and
/// the sort key blends difficulty rank with shuffled position by `w` —
/// a pure permutation, drawing no RNG.
fn curriculum_reorder(
    xs: &mut Vec<f32>,
    ys: &mut Vec<i32>,
    n: usize,
    d: usize,
    classes: usize,
    w: f64,
) {
    let mut cents = vec![0.0f64; classes * d];
    let mut ccount = vec![0usize; classes];
    for i in 0..n {
        let c = ys[i] as usize;
        ccount[c] += 1;
        for t in 0..d {
            cents[c * d + t] += xs[i * d + t] as f64;
        }
    }
    for c in 0..classes {
        let m = ccount[c].max(1) as f64;
        for t in 0..d {
            cents[c * d + t] /= m;
        }
    }
    let mut diff = vec![0.0f64; n];
    for i in 0..n {
        let c = ys[i] as usize;
        let mut s = 0.0;
        for t in 0..d {
            let v = xs[i * d + t] as f64 - cents[c * d + t];
            s += v * v;
        }
        diff[i] = s;
    }
    let mut by_diff: Vec<usize> = (0..n).collect();
    by_diff.sort_by(|&a, &b| diff[a].total_cmp(&diff[b]).then(a.cmp(&b)));
    let mut rank = vec![0usize; n];
    for (r, &i) in by_diff.iter().enumerate() {
        rank[i] = r;
    }
    let w = w.min(1.0);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let ka = w * rank[a] as f64 + (1.0 - w) * a as f64;
        let kb = w * rank[b] as f64 + (1.0 - w) * b as f64;
        ka.total_cmp(&kb).then(a.cmp(&b))
    });
    let mut xs2 = vec![0.0f32; n * d];
    let mut ys2 = vec![0i32; n];
    for (new, &old) in order.iter().enumerate() {
        xs2[new * d..(new + 1) * d].copy_from_slice(&xs[old * d..(old + 1) * d]);
        ys2[new] = ys[old];
    }
    *xs = xs2;
    *ys = ys2;
}

fn normalise_cols(x: &mut [f32], n: usize, d: usize) {
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += x[i * d + j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let v = x[i * d + j] as f64 - mean;
            var += v * v;
        }
        let std = (var / n as f64).sqrt().max(1e-6);
        for i in 0..n {
            x[i * d + j] = ((x[i * d + j] as f64 - mean) / std) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SynthSpec {
        SynthSpec {
            name: "test", n: 400, d: 32, classes: 4, intra_rank: 3, modes: 2,
            separation: 2.0, noise: 1.0, redundancy: 0.3, label_noise: 0.0,
            imbalance: 0.0, shift_point: 0.0, curriculum: 0.0, seed: 99,
        }
    }

    #[test]
    fn shapes_and_balance() {
        let ds = synth_dataset(&small_spec());
        assert_eq!(ds.n, 400);
        assert_eq!(ds.d, 32);
        let counts = ds.class_counts();
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn deterministic() {
        let a = synth_dataset(&small_spec());
        let b = synth_dataset(&small_spec());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn normalised() {
        let ds = synth_dataset(&small_spec());
        // Column 0 mean ≈ 0, std ≈ 1.
        let mut mean = 0.0;
        for i in 0..ds.n {
            mean += ds.row(i)[0] as f64;
        }
        mean /= ds.n as f64;
        assert!(mean.abs() < 1e-4);
    }

    #[test]
    fn classes_separable_by_centroid() {
        // Nearest-centroid accuracy must beat chance by a wide margin —
        // the signal the selector is supposed to preserve.
        let ds = synth_dataset(&small_spec());
        let (tr, te) = ds.split(0.8, 1);
        let d = ds.d;
        let mut cents = vec![vec![0.0f64; d]; ds.classes];
        let counts = tr.class_counts();
        for i in 0..tr.n {
            let c = tr.y[i] as usize;
            for (t, &v) in tr.row(i).iter().enumerate() {
                cents[c][t] += v as f64;
            }
        }
        for (c, cent) in cents.iter_mut().enumerate() {
            for v in cent.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..te.n {
            let row = te.row(i);
            let mut best = (f64::MAX, 0usize);
            for (c, cent) in cents.iter().enumerate() {
                let dist: f64 = row
                    .iter()
                    .zip(cent)
                    .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == te.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.n as f64;
        assert!(acc > 0.6, "nearest-centroid acc {acc}");
    }

    #[test]
    fn imbalance_knob_skews_counts_deterministically() {
        let mut s = small_spec();
        s.imbalance = 0.4;
        let counts = class_counts_for(&s);
        assert_eq!(counts.iter().sum::<usize>(), 400, "{counts:?}");
        assert!(counts.windows(2).all(|w| w[0] >= w[1]), "non-increasing: {counts:?}");
        assert!(counts[0] > counts[s.classes - 1], "head must dominate tail: {counts:?}");
        assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
        let ds = synth_dataset(&s);
        assert_eq!(ds.class_counts(), counts, "generator honours the profile");
        let ds2 = synth_dataset(&s);
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
        // knob = 0 keeps the balanced legacy profile.
        assert_eq!(class_counts_for(&small_spec()), vec![100; 4]);
    }

    #[test]
    fn shift_knob_leaves_pre_shift_prefix_bit_identical() {
        let base = synth_dataset(&small_spec());
        let mut s = small_spec();
        s.shift_point = 0.5;
        let shifted = synth_dataset(&s);
        let (d, cut) = (base.d, 200usize);
        assert_eq!(base.y, shifted.y, "labels untouched by the shift");
        assert_eq!(
            &base.x[..cut * d],
            &shifted.x[..cut * d],
            "rows before the shift point are bit-identical to knob = 0"
        );
        assert!(
            base.x[cut * d..] != shifted.x[cut * d..],
            "rows after the shift point must move"
        );
        // Same seed → same shifted dataset.
        let again = synth_dataset(&s);
        assert_eq!(shifted.x, again.x);
    }

    #[test]
    fn curriculum_knob_is_a_pure_difficulty_sort() {
        let base = synth_dataset(&small_spec());
        let mut s = small_spec();
        s.curriculum = 1.0;
        let cur = synth_dataset(&s);
        // Pure permutation: same row multiset (compare via sorted row keys).
        let key = |ds: &crate::data::Dataset, i: usize| {
            let mut k: Vec<u32> = ds.row(i).iter().map(|v| v.to_bits()).collect();
            k.push(ds.y[i] as u32);
            k
        };
        let mut a: Vec<Vec<u32>> = (0..base.n).map(|i| key(&base, i)).collect();
        let mut b: Vec<Vec<u32>> = (0..cur.n).map(|i| key(&cur, i)).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b, "curriculum must permute, not alter, the rows");
        // Easy→hard: recompute the difficulty proxy and check monotone.
        let d = cur.d;
        let mut cents = vec![vec![0.0f64; d]; cur.classes];
        let counts = cur.class_counts();
        for i in 0..cur.n {
            let c = cur.y[i] as usize;
            for (t, &v) in cur.row(i).iter().enumerate() {
                cents[c][t] += v as f64;
            }
        }
        for (c, cent) in cents.iter_mut().enumerate() {
            for v in cent.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let diff = |i: usize| -> f64 {
            let c = cur.y[i] as usize;
            cur.row(i)
                .iter()
                .zip(&cents[c])
                .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                .sum()
        };
        let violations = (1..cur.n).filter(|&i| diff(i) + 1e-9 < diff(i - 1)).count();
        assert_eq!(violations, 0, "curriculum = 1 must be sorted easy→hard");
        assert!(base.x != cur.x, "ordering actually changed");
    }

    #[test]
    fn catalogue_entries_resolve() {
        for name in ["cifar10", "cifar100", "fashionmnist", "tinyimagenet", "caltech256", "dermamnist"] {
            let s = spec(name).unwrap();
            assert_eq!(s.name, name);
        }
        assert!(spec("nope").is_none());
    }
}

//! Synthetic dataset family standing in for the paper's image benchmarks.
//!
//! Each class c lives on its own low-rank affine subspace: a class mean
//! μ_c plus a per-class basis B_c ∈ R^{d×r_intra} with Gaussian loadings,
//! plus isotropic noise and a fraction of near-duplicate samples.  This
//! gives the two properties subset selection dynamics depend on
//! (DESIGN.md §2): dominant low-rank structure for the feature extractor
//! to find, and intra-class redundancy for MaxVol to exploit — a diverse
//! R-subset genuinely carries most of the batch's information.

use super::Dataset;
use crate::rng::Rng;

/// Specification of one synthetic classification dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
    /// Intra-class subspace rank.
    pub intra_rank: usize,
    /// Sub-clusters (modes) per class: classes are multi-modal mosaics, so
    /// small fractions under-cover the modes and accuracy genuinely rises
    /// with data — the sample-complexity axis of Fig 3 / Tables 8-14.
    pub modes: usize,
    /// Class-mean separation (signal strength).
    pub separation: f64,
    /// Isotropic noise σ.
    pub noise: f64,
    /// Fraction of samples that are near-duplicates of another sample in
    /// the same class (redundancy the sampler can prune "for free").
    pub redundancy: f64,
    /// Fraction of labels flipped uniformly (annotation noise — keeps the
    /// task from being linearly saturated and differentiates selectors).
    pub label_noise: f64,
    pub seed: u64,
}

/// Catalogue matching `python/compile/configs.py` shapes. The n values are
/// laptop-scale stand-ins for the real datasets (DESIGN.md §2); class
/// counts match the originals.
pub fn spec(name: &str) -> Option<SynthSpec> {
    let s = match name {
        "cifar10" => SynthSpec {
            name: "cifar10", n: 12_800, d: 256, classes: 10, intra_rank: 8, modes: 32,
            separation: 1.0, noise: 1.0, redundancy: 0.3, label_noise: 0.01, seed: 0xC1FA_0010,
        },
        "cifar100" => SynthSpec {
            name: "cifar100", n: 12_800, d: 256, classes: 100, intra_rank: 4, modes: 8,
            separation: 0.9, noise: 1.0, redundancy: 0.25, label_noise: 0.02, seed: 0xC1FA_0100,
        },
        "fashionmnist" => SynthSpec {
            name: "fashionmnist", n: 12_800, d: 196, classes: 10, intra_rank: 6, modes: 24,
            separation: 1.15, noise: 1.0, redundancy: 0.35, label_noise: 0.01, seed: 0xFA50_0010,
        },
        "tinyimagenet" => SynthSpec {
            name: "tinyimagenet", n: 12_800, d: 256, classes: 200, intra_rank: 3, modes: 5,
            separation: 0.82, noise: 1.0, redundancy: 0.2, label_noise: 0.02, seed: 0x7191_0200,
        },
        "caltech256" => SynthSpec {
            name: "caltech256", n: 10_280, d: 256, classes: 257, intra_rank: 3, modes: 4,
            separation: 0.85, noise: 1.0, redundancy: 0.2, label_noise: 0.02, seed: 0xCA17_0257,
        },
        "dermamnist" => SynthSpec {
            name: "dermamnist", n: 7_000, d: 147, classes: 7, intra_rank: 5, modes: 26,
            separation: 0.9, noise: 1.0, redundancy: 0.3, label_noise: 0.02, seed: 0xDE3A_0007,
        },
        _ => return None,
    };
    Some(s)
}

pub fn synth_dataset(spec: &SynthSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let d = spec.d;
    // Class means on a random sphere of radius `separation`.
    let sqrt_d = (d as f64).sqrt();
    // Mode means: each class is a mosaic of `modes` sub-clusters.  Modes
    // of *different* classes are interleaved at the same scale, so the
    // decision boundary is locally fine-grained: a training set must cover
    // most modes before accuracy saturates.
    let mode_scale = spec.separation * sqrt_d / 2.0;
    let mut mode_means: Vec<Vec<Vec<f64>>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut ms = Vec::with_capacity(spec.modes);
        for _ in 0..spec.modes.max(1) {
            let mut m = rng.normals(d);
            let n = crate::linalg::norm2(&m);
            let scale = mode_scale / n.max(1e-12) * (1.0 + rng.uniform());
            for v in &mut m {
                *v *= scale;
            }
            ms.push(m);
        }
        mode_means.push(ms);
    }
    // Per-class bases: direction r carries energy ∝ 1/(r+1) with the
    // leading direction comparable to the noise — enough low-rank
    // structure for the extractor to find without swamping the class
    // signal.
    let mut bases: Vec<Vec<Vec<f64>>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut b = Vec::with_capacity(spec.intra_rank);
        for r in 0..spec.intra_rank {
            let mut v = rng.normals(d);
            let n = crate::linalg::norm2(&v);
            let scale = 1.2 * sqrt_d / (n.max(1e-12) * (r as f64 + 1.0));
            for x in &mut v {
                *x *= scale;
            }
            b.push(v);
        }
        bases.push(b);
    }

    let per_class = spec.n / spec.classes;
    let n = per_class * spec.classes;
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0i32; n];
    let mut idx = 0usize;
    for c in 0..spec.classes {
        let mut class_rows: Vec<usize> = Vec::new();
        for _k in 0..per_class {
            let dup = !class_rows.is_empty() && rng.uniform() < spec.redundancy;
            let mut row = vec![0.0f64; d];
            if dup {
                let src = class_rows[rng.below(class_rows.len())];
                for t in 0..d {
                    row[t] = x[src * d + t] as f64 + 0.05 * spec.noise * rng.normal();
                }
            } else {
                let mode = rng.below(spec.modes.max(1));
                row.copy_from_slice(&mode_means[c][mode]);
                for b in &bases[c] {
                    let load = rng.normal();
                    for t in 0..d {
                        row[t] += load * b[t];
                    }
                }
                for t in 0..d {
                    row[t] += 0.6 * spec.noise * rng.normal();
                }
            }
            for t in 0..d {
                x[idx * d + t] = row[t] as f32;
            }
            y[idx] = c as i32;
            class_rows.push(idx);
            idx += 1;
        }
    }
    // Normalise features globally to zero mean / unit variance per dim
    // (what image pipelines do), then shuffle rows.
    normalise_cols(&mut x, n, d);
    let perm = rng.permutation(n);
    let mut xs = vec![0.0f32; n * d];
    let mut ys = vec![0i32; n];
    for (new, &old) in perm.iter().enumerate() {
        xs[new * d..(new + 1) * d].copy_from_slice(&x[old * d..(old + 1) * d]);
        ys[new] = y[old];
    }
    if spec.label_noise > 0.0 {
        for yv in ys.iter_mut() {
            if rng.uniform() < spec.label_noise {
                *yv = rng.below(spec.classes) as i32;
            }
        }
    }
    Dataset::new(spec.name, xs, ys, d, spec.classes)
}

fn normalise_cols(x: &mut [f32], n: usize, d: usize) {
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += x[i * d + j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let v = x[i * d + j] as f64 - mean;
            var += v * v;
        }
        let std = (var / n as f64).sqrt().max(1e-6);
        for i in 0..n {
            x[i * d + j] = ((x[i * d + j] as f64 - mean) / std) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SynthSpec {
        SynthSpec {
            name: "test", n: 400, d: 32, classes: 4, intra_rank: 3, modes: 2,
            separation: 2.0, noise: 1.0, redundancy: 0.3, label_noise: 0.0, seed: 99,
        }
    }

    #[test]
    fn shapes_and_balance() {
        let ds = synth_dataset(&small_spec());
        assert_eq!(ds.n, 400);
        assert_eq!(ds.d, 32);
        let counts = ds.class_counts();
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn deterministic() {
        let a = synth_dataset(&small_spec());
        let b = synth_dataset(&small_spec());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn normalised() {
        let ds = synth_dataset(&small_spec());
        // Column 0 mean ≈ 0, std ≈ 1.
        let mut mean = 0.0;
        for i in 0..ds.n {
            mean += ds.row(i)[0] as f64;
        }
        mean /= ds.n as f64;
        assert!(mean.abs() < 1e-4);
    }

    #[test]
    fn classes_separable_by_centroid() {
        // Nearest-centroid accuracy must beat chance by a wide margin —
        // the signal the selector is supposed to preserve.
        let ds = synth_dataset(&small_spec());
        let (tr, te) = ds.split(0.8, 1);
        let d = ds.d;
        let mut cents = vec![vec![0.0f64; d]; ds.classes];
        let counts = tr.class_counts();
        for i in 0..tr.n {
            let c = tr.y[i] as usize;
            for (t, &v) in tr.row(i).iter().enumerate() {
                cents[c][t] += v as f64;
            }
        }
        for (c, cent) in cents.iter_mut().enumerate() {
            for v in cent.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..te.n {
            let row = te.row(i);
            let mut best = (f64::MAX, 0usize);
            for (c, cent) in cents.iter().enumerate() {
                let dist: f64 = row
                    .iter()
                    .zip(cent)
                    .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == te.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.n as f64;
        assert!(acc > 0.6, "nearest-centroid acc {acc}");
    }

    #[test]
    fn catalogue_entries_resolve() {
        for name in ["cifar10", "cifar100", "fashionmnist", "tinyimagenet", "caltech256", "dermamnist"] {
            let s = spec(name).unwrap();
            assert_eq!(s.name, name);
        }
        assert!(spec("nope").is_none());
    }
}

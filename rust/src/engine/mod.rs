//! `SelectionEngine` — the one typed facade over every selection
//! execution shape.
//!
//! Before this module, "scale" was four loosely-coupled `TrainConfig`
//! knobs (`shards`, `merge`, `pool_workers`, `overlap`) whose validity
//! rules, method-aware defaults, and fallbacks were duplicated across the
//! CLI, `TrainConfig::default`, and ~600 lines of trainer hand-wiring —
//! and selection *results* leaked out through per-type side channels
//! (`last_rank_decision`, `rank_stats`, `with_rank_authority`).  The
//! engine replaces all of that with one boundary:
//!
//! * [`EngineBuilder`] — method, budget/fraction, typed
//!   [`ExecShape`]`::{Serial, Sharded, Pooled}`,
//!   [`MergePolicy`](crate::coordinator::MergePolicy),
//!   [`RankMode`]`::{Strict, Adaptive}`, extractor, seed.  Every
//!   cross-knob rule (overlap ⇒ pool, non-shardable ⇒ serial-with-note,
//!   method-aware merge default) is validated **here and only here**;
//!   invalid combinations return a typed [`EngineError`] naming the
//!   offending field.
//! * [`SelectionEngine::select`] — the hot path: feed a
//!   [`BatchView`](crate::selection::BatchView), get a first-class
//!   [`Selection`] (indices into a reused buffer, the dynamic-rank
//!   decision, refresh telemetry).  No side-channel accessors.
//! * [`SelectionEngine::windows`] — the streaming session: drive N
//!   assembled windows through the engine, overlapping next-window
//!   assembly with in-flight pooled selection when the shape says so
//!   (the pipeline previously inlined in the trainer).
//!
//! Internally the engine owns the [`Workspace`](crate::linalg::Workspace),
//! the result buffer, the sharded/pooled coordinator wrappers, and the
//! single gradient-merge rank authority, so the bit-identity guarantees
//! pinned by `tests/sharded_selection.rs`, `tests/selection_pool.rs`, and
//! `tests/gradient_merge.rs` hold unchanged through the facade (pinned
//! again, through the facade, by `tests/engine_api.rs`).
//!
//! # Quickstart
//!
//! ```
//! use graft::engine::{EngineBuilder, ExecShape};
//! use graft::linalg::Mat;
//! use graft::rng::Rng;
//! use graft::selection::BatchView;
//!
//! let k = 8;
//! let mut rng = Rng::new(7);
//! let features = Mat::from_fn(k, 3, |_, _| rng.normal());
//! let grads = Mat::from_fn(k, 4, |_, _| rng.normal());
//! let losses = vec![1.0; k];
//! let labels = vec![0i32; k];
//! let preds = vec![0i32; k];
//! let row_ids: Vec<usize> = (0..k).collect();
//! let batch = BatchView {
//!     features: &features,
//!     grads: &grads,
//!     losses: &losses,
//!     labels: &labels,
//!     preds: &preds,
//!     classes: 2,
//!     row_ids: &row_ids,
//! };
//!
//! let mut eng = EngineBuilder::new()
//!     .method("graft")
//!     .budget(4)
//!     .exec(ExecShape::Serial)
//!     .build()
//!     .expect("valid configuration");
//! let sel = eng.select(&batch).expect("selection fault");
//! assert_eq!(sel.indices.len(), 4);
//! assert!(sel.degradations.is_empty(), "healthy run");
//! println!("kept {:?} (decision {:?})", sel.indices, sel.decision);
//! ```
//!
//! Since the fault-tolerance PR, `select` returns
//! `Result<Selection, `[`SelectError`]`>` and the engine runs a
//! configurable [`FaultPolicy`] (typed failure / retry with respawn /
//! degradation ladder) — see [`EngineBuilder::fault_policy`] and the
//! crate-level docs for the error taxonomy.
//!
//! The streaming PR adds a second session type:
//! [`EngineBuilder::build_streaming`] constructs a [`StreamingEngine`]
//! that ingests rows in chunks of any size under a bounded reservoir
//! (memory O(budget·width) regardless of stream length) and materialises
//! a [`StreamSnapshot`] on demand — bit-identical to the batch engine
//! whenever the stream fits the reservoir.  See [`stream`](self) docs on
//! [`StreamingEngine`] for the guarantees.

mod builder;
mod select;
mod stream;

pub use builder::{default_merge, EngineBuilder, EngineError, ExecShape, PivotMode, RankMode};
pub use select::{Selection, SelectionEngine};
pub use stream::{StreamSnapshot, StreamingEngine};

pub use crate::coordinator::fault::{
    Degradation, FaultPolicy, PoolStats, SelectError, WindowsError,
};

//! The built engine: hot-path [`SelectionEngine::select`] and the
//! streaming [`SelectionEngine::windows`] session — both fallible, both
//! driving the configured [`FaultPolicy`] (quarantine → retry →
//! degradation ladder) so a selection either matches the paper's
//! criterion, carries a recorded [`Degradation`], or fails with a typed
//! [`SelectError`].  Never a panic, and never a silently-different subset.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::coordinator::{
    Degradation, FaultPolicy, MergePolicy, PoolStats, PooledSelector, SelectError, SelectWindow,
    ShardedSelector, WindowsError,
};
use crate::faults::{FaultAction, FaultInjector, ShardCtx};
use crate::features::FeatureExtractor;
use crate::graft::{RankDecision, RankStats, StrictRankTally};
use crate::linalg::{Mat, Workspace};
use crate::rng::Rng;
use crate::selection::maxvol::FastMaxVol;
use crate::selection::{BatchView, Selector};

use super::builder::ExecShape;

/// The resolved execution backend.  All three are bit-identical for the
/// same method and seed; see [`ExecShape`].
pub(super) enum Exec {
    Serial(Box<dyn Selector>),
    Sharded(Box<ShardedSelector>),
    Pooled(Box<PooledSelector>),
}

impl Exec {
    fn rank_stats(&self) -> Option<RankStats> {
        match self {
            Exec::Serial(s) => s.rank_stats(),
            Exec::Sharded(s) => s.rank_stats(),
            Exec::Pooled(p) => p.rank_stats(),
        }
    }

    fn last_decision(&self) -> Option<RankDecision> {
        match self {
            // The serial decision maker is the selector itself.
            Exec::Serial(s) => s.rank_stats().and_then(|t| t.last),
            // Sharded/pooled: the gradient-merge authority's decision
            // (None under feature-only merges, and for a one-shard pool,
            // whose inner selector lives on a worker thread).
            Exec::Sharded(s) => {
                s.last_rank_decision().or_else(|| s.rank_stats().and_then(|t| t.last))
            }
            Exec::Pooled(p) => p.last_rank_decision(),
        }
    }
}

/// One selection result — the first-class replacement for the per-type
/// side-channel accessors.  Borrows the engine's reused buffers, so
/// holding a `Selection` holds the engine; copy the indices out if you
/// need them across selects.
pub struct Selection<'e> {
    /// Batch-local winner ids (indices into the selected batch's rows),
    /// unique, in selection order.  When rows were quarantined these
    /// still index the *original* batch — the engine maps the winners of
    /// the filtered copy back before returning.
    pub indices: &'e [usize],
    /// The dynamic-rank decision behind this subset (methods without a
    /// rank stage, feature-only merges, one-shard pools — whose inner
    /// selector lives on a worker thread — and degraded selections report
    /// `None`).
    pub decision: Option<RankDecision>,
    /// The budget this selection was asked for (`min(r, K)` rows come
    /// back for budget-honouring methods).
    pub budget: usize,
    /// 0-based running index of this selection in the engine's lifetime
    /// (windows and one-shot selects share the counter).
    pub window: u64,
    /// Every step this selection took down the degradation ladder
    /// (quarantined rows, feature-only fallback, seeded-random fallback),
    /// in order.  Empty for a healthy paper-criterion selection — check
    /// this before treating the subset as GRAFT's.
    pub degradations: &'e [Degradation],
}

/// A built selection engine: owns the selector(s) in their execution
/// shape, the scratch [`Workspace`], the result buffer, the validated
/// feature extractor, the single gradient-merge rank authority, and the
/// fault machinery (policy, quarantine buffers, telemetry).  Construct
/// with [`EngineBuilder`](super::EngineBuilder).
pub struct SelectionEngine {
    exec: Exec,
    /// Retained selector factory for the serial shape — the engine-level
    /// mirror of the pool's respawn factory, re-run after a contained
    /// panic so retries (and later selects) never reuse a suspect
    /// instance.  `None` on sharded/pooled shapes, which rebuild through
    /// their own machinery ([`ShardedSelector::rebuild_workers`], pool
    /// worker respawn).
    rebuild: Option<Box<dyn FnMut(usize) -> Box<dyn Selector> + Send>>,
    extractor: Option<Box<dyn FeatureExtractor>>,
    shape: ExecShape,
    merge: MergePolicy,
    fraction: f64,
    budget: Option<usize>,
    policy: FaultPolicy,
    /// Engine seed: deterministic stream for the seeded-random ladder rung
    /// (mixed with the window ordinal, so each degraded window draws a
    /// different but reproducible subset).
    seed: u64,
    /// Fault injector consulted on the serial path (sharded/pooled shapes
    /// hold their own copy, installed via
    /// [`SelectionEngine::set_fault_injector`]).
    injector: Option<Arc<dyn FaultInjector>>,
    ws: Workspace,
    buf: Vec<usize>,
    /// Degradations recorded by the most recent `select` call (or
    /// accumulated across the most recent `windows` session).
    degr: Vec<Degradation>,
    /// Engine-side fault telemetry (select retries, quarantined rows);
    /// merged with the pool's counters by
    /// [`SelectionEngine::fault_stats`].
    stats: PoolStats,
    /// Scratch for the quarantine scan (poisoned row indices).
    qrows: Vec<usize>,
    /// Original batch-local index of each kept row of the filtered copy
    /// (the winner remap table).
    qkept: Vec<usize>,
    /// Administrative strict-rank accounting for sharded/pooled
    /// gradient-aware shapes in strict mode, where no rank authority is
    /// installed (the adaptive-only carry: a strict post-merge cut is
    /// provably the identity, so nothing downstream of the merge ever
    /// computes a decision).  The engine records `|subset|` per healthy
    /// window here — exactly the rank the removed authority would have
    /// decided — and synthesises the surfaced [`RankDecision`] from it.
    strict_tally: Option<StrictRankTally>,
    notes: Vec<String>,
    windows_done: u64,
}

impl SelectionEngine {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn from_parts(
        mut exec: Exec,
        rebuild: Option<Box<dyn FnMut(usize) -> Box<dyn Selector> + Send>>,
        extractor: Option<Box<dyn FeatureExtractor>>,
        shape: ExecShape,
        merge: MergePolicy,
        fraction: f64,
        budget: Option<usize>,
        policy: FaultPolicy,
        seed: u64,
        strict_tally: Option<StrictRankTally>,
        notes: Vec<String>,
    ) -> SelectionEngine {
        // The pool runs shard-level retries itself (respawn + resubmit);
        // the engine layers quarantine and the ladder on top.  One policy
        // configures both.
        if let Exec::Pooled(p) = &mut exec {
            p.set_fault_policy(policy);
        }
        SelectionEngine {
            exec,
            rebuild,
            extractor,
            shape,
            merge,
            fraction,
            budget,
            policy,
            seed,
            injector: None,
            ws: Workspace::new(),
            buf: Vec::new(),
            degr: Vec::new(),
            stats: PoolStats::default(),
            qrows: Vec::new(),
            qkept: Vec::new(),
            strict_tally,
            notes,
            windows_done: 0,
        }
    }

    /// The resolved execution shape (after any non-shardable fallback).
    pub fn shape(&self) -> ExecShape {
        self.shape
    }

    /// The resolved merge policy.
    pub fn merge(&self) -> MergePolicy {
        self.merge
    }

    /// The configured fault policy.
    pub fn fault_policy(&self) -> FaultPolicy {
        self.policy
    }

    /// Build-time fallback notes (e.g. a non-shardable method downgraded
    /// to serial); empty when the configuration applied as requested.
    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    /// The engine-owned feature extractor, when one was configured.
    pub fn extractor(&self) -> Option<&dyn FeatureExtractor> {
        self.extractor.as_deref()
    }

    /// Per-batch row budget for a K-row batch: the explicit
    /// [`budget`](super::EngineBuilder::budget) if set, else
    /// `round(fraction · K)` clamped to `[1, K]`.
    pub fn budget_for(&self, k: usize) -> usize {
        resolve_budget(self.budget, self.fraction, k)
    }

    /// Dynamic-rank accounting of the single decision maker — the
    /// coordinator's rank authority on sharded/pooled gradient-aware
    /// shapes, or the selector's own policy on the serial path.  `None`
    /// for methods without a rank stage (and for a one-shard pool, whose
    /// inner selector lives on a worker thread).
    ///
    /// Sharded/pooled gradient-aware shapes in **strict** mode carry no
    /// rank authority (the post-merge cut is the identity there); the
    /// engine's own strict tally supplies the equivalent accounting.
    pub fn rank_stats(&self) -> Option<RankStats> {
        self.exec
            .rank_stats()
            .or_else(|| self.strict_tally.as_ref().map(|t| t.stats()))
    }

    /// Decision behind the most recent selection (same caveats as
    /// [`SelectionEngine::rank_stats`]).
    pub fn last_decision(&self) -> Option<RankDecision> {
        self.exec
            .last_decision()
            .or_else(|| self.strict_tally.as_ref().and_then(|t| t.stats().last))
    }

    /// Bytes of gradient-sketch columns currently resident in the
    /// coordinator's carry buffers (zero on the serial shape, and pinned
    /// to zero on strict sharded/pooled shapes by the adaptive-only
    /// carry).  Test/bench telemetry, not a stable API.
    #[doc(hidden)]
    pub fn carried_sketch_bytes(&self) -> usize {
        match &self.exec {
            Exec::Serial(_) => 0,
            Exec::Sharded(s) => s.carried_sketch_bytes(),
            Exec::Pooled(p) => p.carried_sketch_bytes(),
        }
    }

    /// Fault-path telemetry: engine-side counters (retries, quarantined
    /// rows) merged with the pool's (respawns, deadline requeues,
    /// shutdown join timeouts).  All-zero on a healthy run.
    pub fn fault_stats(&self) -> PoolStats {
        let pool = match &self.exec {
            Exec::Pooled(p) => p.stats(),
            _ => PoolStats::default(),
        };
        self.stats.merged(pool)
    }

    /// Degradations recorded by the most recent [`SelectionEngine::select`]
    /// (also available on the returned [`Selection`]) or accumulated over
    /// the most recent [`SelectionEngine::windows`] session.
    pub fn last_degradations(&self) -> &[Degradation] {
        &self.degr
    }

    /// Selections completed over this engine's lifetime (the counter
    /// behind [`Selection::window`]).
    pub fn windows_done(&self) -> u64 {
        self.windows_done
    }

    /// Live pool worker threads, for telemetry: `Some(n)` on the pooled
    /// shape (see [`crate::coordinator::PooledSelector::live_workers`]),
    /// `None` for serial/sharded engines, which have no resident workers.
    pub fn live_workers(&self) -> Option<usize> {
        match &self.exec {
            Exec::Pooled(p) => Some(p.live_workers()),
            _ => None,
        }
    }

    /// Install (or clear) a deterministic fault injector (tests/benches
    /// only): consulted before every unit of selection work on whichever
    /// execution shape this engine runs.
    pub fn set_fault_injector(&mut self, injector: Option<Arc<dyn FaultInjector>>) {
        match &mut self.exec {
            Exec::Serial(_) => {}
            Exec::Sharded(s) => s.set_fault_injector(injector.clone()),
            Exec::Pooled(p) => p.set_fault_injector(injector.clone()),
        }
        self.injector = injector;
    }

    /// Select a subset from one batch under the configured fault policy.
    ///
    /// The healthy path is unchanged from the infallible days — scratch
    /// and the result buffer are engine-owned and reused, so steady-state
    /// selection performs no heap allocations (pinned by
    /// `tests/alloc_free.rs` on the underlying executors), and zero-fault
    /// results are bit-identical under every [`FaultPolicy`].  On a fault:
    ///
    /// 1. Non-finite rows are quarantined (one vectorized pre-scan).
    ///    Under `Fail`/`Retry` that is [`SelectError::PoisonedInput`];
    ///    under `Degrade` the rows are excluded, reported in
    ///    [`Selection::degradations`], and the winners mapped back to
    ///    original batch-local indices.
    /// 2. A panicking selector (or failing pool shard) is retried within
    ///    the policy's budget — bit-identical on success.  A contained
    ///    panic first rebuilds the suspect selector(s) and workspace from
    ///    the retained factory (counted in [`PoolStats::respawns`]),
    ///    mirroring the pool's worker respawn, so neither the retry nor
    ///    any later select reuses torn state; the coordinator-side rank
    ///    authority survives untouched.
    /// 3. Numerical breakdown (degenerate MaxVol pivots, non-finite
    ///    projection errors) is deterministic, never retried, and under
    ///    `Degrade` skips straight to the seeded-random rung.
    /// 4. Under `Degrade`, exhausted retries walk the ladder: feature-only
    ///    Fast MaxVol, then a seeded-random subset — each recorded.
    pub fn select(&mut self, view: &BatchView<'_>) -> Result<Selection<'_>, SelectError> {
        self.degr.clear();
        scan_poisoned(view, &mut self.qrows);
        let quarantined = !self.qrows.is_empty();
        let qwin;
        let qview;
        let view: &BatchView<'_> = if quarantined {
            if !matches!(self.policy, FaultPolicy::Degrade) {
                return Err(SelectError::PoisonedInput { rows: self.qrows.clone() });
            }
            self.stats.quarantined_rows += self.qrows.len() as u64;
            self.degr.push(Degradation::Quarantined { rows: self.qrows.clone() });
            qwin = filtered_window(view, &self.qrows, &mut self.qkept);
            qview = qwin.view();
            &qview
        } else {
            view
        };
        let window = self.windows_done;
        let r = resolve_budget(self.budget, self.fraction, view.k());
        let SelectionEngine {
            exec, rebuild, policy, seed, injector, ws, buf, degr, stats, qkept, ..
        } = self;
        // Shard-level faults on the pooled shape are already retried by
        // the pool itself (respawn + resubmit with the same inputs); an
        // engine-level loop on top would square the budget.
        let retries = if matches!(exec, Exec::Pooled(_)) { 0 } else { policy.max_retries() };
        let mut attempt = 0u32;
        let mut result = loop {
            let mut suspect = false;
            let res = attempt_select(
                exec,
                injector.as_deref(),
                window,
                view,
                r,
                ws,
                buf,
                attempt,
                &mut suspect,
            );
            if suspect {
                // A contained (non-injected) panic may have left selector
                // and workspace state torn: rebuild both from the retained
                // factory — exactly what the pool's worker respawn does —
                // before deciding retry-vs-bail, so the engine is healthy
                // for subsequent selects either way.  The coordinator-side
                // rank authority survives (the panic re-raised before any
                // merge ran), which keeps adaptive-rank retries
                // bit-identical.
                stats.respawns += 1;
                *ws = Workspace::new();
                match exec {
                    Exec::Serial(s) => {
                        if let Some(mk) = rebuild.as_mut() {
                            *s = mk(0);
                        }
                    }
                    Exec::Sharded(sh) => sh.rebuild_workers(),
                    Exec::Pooled(_) => {}
                }
            }
            match res {
                Err(e) if e.retryable() && attempt < retries => {
                    attempt += 1;
                    stats.retries += 1;
                    let backoff = policy.backoff();
                    if backoff > std::time::Duration::ZERO {
                        std::thread::sleep(backoff);
                    }
                }
                other => break other,
            }
        };
        if matches!(*policy, FaultPolicy::Degrade) {
            if let Err(e) = result {
                result = run_ladder(e, view, r, *seed, window, ws, buf, degr);
            }
        }
        result?;
        if quarantined {
            // Winners index the filtered copy; map them back so callers
            // can index the original batch arrays.
            for i in buf.iter_mut() {
                *i = qkept[*i];
            }
        }
        self.windows_done += 1;
        let degraded = !self.degr.is_empty();
        // Strict sharded/pooled shapes carry no rank authority; tally the
        // merged subset size — exactly the rank the authority's identity
        // cut would have decided — whenever the merge itself produced the
        // subset.  Ladder output is not a rank decision and is skipped
        // (quarantine-only windows still ran the merge, so they count,
        // mirroring the old authority's accounting).
        let laddered = self.degr.iter().any(|d| {
            matches!(d, Degradation::FeatureOnlyMaxVol { .. } | Degradation::SeededRandom { .. })
        });
        let fresh = match self.strict_tally.as_mut() {
            Some(t) if !laddered && !self.buf.is_empty() => Some(t.record(self.buf.len())),
            _ => None,
        };
        Ok(Selection {
            indices: &self.buf,
            // A degraded subset was not produced by the rank criterion;
            // whatever decision the executor last made does not describe
            // it.
            decision: if degraded { None } else { self.exec.last_decision().or(fresh) },
            budget: r,
            window: self.windows_done - 1,
            degradations: &self.degr,
        })
    }

    /// Drive `count` selection windows through the engine — the streaming
    /// session that owns the assemble ∥ select overlap pipeline.
    ///
    /// `assemble(w, extractor)` builds window `w` (batch gather, `embed`,
    /// feature extraction — whatever the caller does); the engine passes
    /// its validated extractor in so assembly closures need no selector
    /// knowledge.  `consume(w, window, winners)` receives the batch-local
    /// winner ids for window `w`.
    ///
    /// On a [`ExecShape::Pooled`] shape with `overlap` set, window `w + 1`
    /// is assembled on the calling thread while the pool workers select
    /// window `w`; every other shape runs strictly serial.  The `consume`
    /// stream is identical either way — assembly never depends on
    /// selection results — extending the `run_windows` guarantee pinned by
    /// `tests/selection_pool.rs::overlap_and_serial_paths_agree` to the
    /// facade.
    ///
    /// Every window runs under the engine's [`FaultPolicy`], exactly as in
    /// [`SelectionEngine::select`]; window degradations accumulate in
    /// [`SelectionEngine::last_degradations`] and the counters in
    /// [`SelectionEngine::fault_stats`].  An `Err` from `assemble` aborts
    /// the loop as [`WindowsError::Assemble`] after draining any in-flight
    /// selection; a selection failure that survives the policy aborts it
    /// as [`WindowsError::Select`].
    pub fn windows<E, A, C>(
        &mut self,
        count: usize,
        mut assemble: A,
        mut consume: C,
    ) -> Result<(), WindowsError<E>>
    where
        // Named generics (not impl-Trait arguments) so callers whose
        // error type is not pinned by inference can turbofish it:
        // `eng.windows::<anyhow::Error, _, _>(...)`.
        A: FnMut(usize, Option<&dyn FeatureExtractor>) -> Result<SelectWindow, E>,
        C: FnMut(usize, &SelectWindow, &[usize]),
    {
        if count == 0 {
            return Ok(());
        }
        if !matches!(self.exec, Exec::Pooled(_)) {
            // Serial / sharded: no overlap to orchestrate, so each window
            // is one fallible `select` — quarantine, retries, and ladder
            // included for free.  `select` resets the degradation log per
            // call, so accumulate the session's here — including on the
            // error paths, so an aborted session still reports every
            // earlier window's recorded degradations.
            let mut acc: Vec<Degradation> = Vec::new();
            for wi in 0..count {
                let win = match assemble(wi, self.extractor.as_deref()) {
                    Ok(w) => w,
                    Err(e) => {
                        self.degr = acc;
                        return Err(WindowsError::Assemble(e));
                    }
                };
                match self.select(&win.view()) {
                    Ok(sel) => consume(wi, &win, sel.indices),
                    Err(e) => {
                        acc.extend(self.degr.iter().cloned());
                        self.degr = acc;
                        return Err(WindowsError::Select(e));
                    }
                }
                acc.extend(self.degr.iter().cloned());
            }
            self.degr = acc;
            return Ok(());
        }
        self.degr.clear();
        let base = self.windows_done;
        let SelectionEngine {
            exec,
            extractor,
            shape,
            fraction,
            budget,
            policy,
            seed,
            ws,
            buf,
            degr,
            stats,
            strict_tally,
            windows_done,
            ..
        } = self;
        let Exec::Pooled(pool) = exec else { unreachable!() };
        let ext = extractor.as_deref();
        let (policy, seed) = (*policy, *seed);
        // Shared fault log for the two closures below (assemble spots
        // poisoned windows, resolve adjudicates them): a RefCell because
        // both need it and the pipeline interleaves their calls.
        struct FaultLog {
            /// Poisoned-row reports per window ordinal, consumed by
            /// `resolve` (with overlap, assembly runs one window ahead).
            poisoned: Vec<(usize, Vec<usize>)>,
            degr: Vec<Degradation>,
            quarantined_rows: u64,
            /// `ws.mv_degenerate` after the previous window's merge — the
            /// per-window breakdown check is the delta against this.
            degen: u64,
        }
        let log = RefCell::new(FaultLog {
            poisoned: Vec::new(),
            degr: Vec::new(),
            quarantined_rows: 0,
            degen: ws.mv_degenerate,
        });
        let mut qrows = std::mem::take(&mut self.qrows);
        let result = crate::coordinator::pool::run_windows_with(
            pool,
            |k| resolve_budget(*budget, *fraction, k),
            matches!(shape, ExecShape::Pooled { overlap: true, .. }),
            count,
            ws,
            buf,
            |wi| {
                let mut win = assemble(wi, ext)?;
                // Quarantine at assembly time, before the window's jobs
                // are submitted.  The window is owned, so under `Degrade`
                // the poisoned rows are compacted away in place (row_ids
                // shift with them — consume sees a consistent window);
                // under `Fail`/`Retry` the rows are only logged and
                // `resolve` raises the typed error for this window.
                scan_poisoned(&win.view(), &mut qrows);
                if !qrows.is_empty() {
                    log.borrow_mut().poisoned.push((wi, qrows.clone()));
                    if matches!(policy, FaultPolicy::Degrade) {
                        quarantine_owned(&mut win, &qrows);
                    }
                }
                Ok(win)
            },
            |wi, win, winners| {
                *windows_done += 1;
                consume(wi, win, winners);
            },
            &mut |wi, view, r, ws, buf, res| {
                let mut l = log.borrow_mut();
                if let Some(pos) = l.poisoned.iter().position(|(w, _)| *w == wi) {
                    let (_, rows) = l.poisoned.swap_remove(pos);
                    if !matches!(policy, FaultPolicy::Degrade) {
                        return Err(SelectError::PoisonedInput { rows });
                    }
                    l.quarantined_rows += rows.len() as u64;
                    l.degr.push(Degradation::Quarantined { rows });
                }
                let degen0 = l.degen;
                drop(l);
                // Post-check: the merge stage runs with this workspace, so
                // a degenerate pivot in it shows up in the counter delta.
                // (Shard-level counters live in the worker workspaces and
                // are owned by their containment; see coordinator README.)
                let checked = res.and_then(|()| {
                    let clamped = ws.mv_degenerate - degen0;
                    if clamped > 0 {
                        Err(SelectError::NumericalBreakdown {
                            stage: "merge-maxvol",
                            detail: format!("{clamped} degenerate pivot(s) clamped"),
                        })
                    } else {
                        Ok(())
                    }
                });
                let merged_ok = checked.is_ok();
                let out = match checked {
                    Err(e) if matches!(policy, FaultPolicy::Degrade) => {
                        let mut l = log.borrow_mut();
                        let view_r = r.min(view.k());
                        run_ladder(e, view, view_r, seed, base + wi as u64, ws, buf, &mut l.degr)
                    }
                    other => other,
                };
                // Strict pools carry no rank authority; tally the merged
                // subset size per healthy window (ladder output is not a
                // rank decision — see `select`).
                if merged_ok && !buf.is_empty() {
                    if let Some(t) = strict_tally.as_mut() {
                        t.record(buf.len());
                    }
                }
                log.borrow_mut().degen = ws.mv_degenerate;
                out
            },
        );
        let l = log.into_inner();
        degr.extend(l.degr);
        stats.quarantined_rows += l.quarantined_rows;
        self.qrows = qrows;
        result
    }

    /// Tear down pooled workers now (otherwise on drop; idempotent; a
    /// no-op for non-pooled shapes).
    pub fn shutdown(&mut self) {
        if let Exec::Pooled(p) = &mut self.exec {
            p.shutdown();
        }
    }
}

/// One attempt at the configured selection: run the executor (with panic
/// containment and serial-path fault injection), then the numerical
/// post-checks.  Errors are typed; retryability is the caller's business.
/// A caught panic sets `suspect` — the caller must then treat the
/// executor's worker-side selector/workspace state as torn and rebuild it
/// before running again.  Injected serial faults are consulted *outside*
/// the containment boundary and return the typed error directly: the
/// selector never ran, so its state (including any adaptive rank
/// accumulator) is untouched and legitimately reused by the retry.
#[allow(clippy::too_many_arguments)]
fn attempt_select(
    exec: &mut Exec,
    injector: Option<&dyn FaultInjector>,
    window: u64,
    view: &BatchView<'_>,
    r: usize,
    ws: &mut Workspace,
    buf: &mut Vec<usize>,
    attempt: u32,
    suspect: &mut bool,
) -> Result<(), SelectError> {
    let degen0 = ws.mv_degenerate;
    match exec {
        Exec::Pooled(p) => p.begin(view, r).finish(ws, buf)?,
        Exec::Serial(s) => {
            if let Some(i) = injector {
                // 1-based window ordinal, matching the pool's epoch
                // convention; shard/worker are 0 on the serial path.
                match i.before_shard(ShardCtx { window: window + 1, shard: 0, worker: 0 }) {
                    FaultAction::None => {}
                    FaultAction::Delay(by) => std::thread::sleep(by),
                    FaultAction::Panic | FaultAction::DieWorker => {
                        return Err(SelectError::ShardFailure { shard: 0, attempts: attempt + 1 });
                    }
                }
            }
            catch_unwind(AssertUnwindSafe(|| s.select_into(view, r, ws, buf))).map_err(|_| {
                *suspect = true;
                SelectError::ShardFailure { shard: 0, attempts: attempt + 1 }
            })?;
        }
        Exec::Sharded(sh) => {
            // A scoped-thread shard panic re-raises on the caller; catch
            // it here exactly like the pool contains its workers.  The
            // failing shard index does not survive the unwind, so the
            // error reports shard 0.  Injected faults panic on the scoped
            // threads, so they are indistinguishable from real ones here —
            // `suspect` covers both, and the worker rebuild is harmless
            // for injected faults (per-shard instances are strict, i.e.
            // selection-stateless).
            catch_unwind(AssertUnwindSafe(|| sh.select_into(view, r, ws, buf))).map_err(|_| {
                *suspect = true;
                SelectError::ShardFailure { shard: 0, attempts: attempt + 1 }
            })?;
        }
    }
    let clamped = ws.mv_degenerate - degen0;
    if clamped > 0 {
        // The volume criterion no longer justifies the subset (duplicate /
        // rank-deficient rows).  Deterministic: retrying cannot help.
        return Err(SelectError::NumericalBreakdown {
            stage: "maxvol",
            detail: format!("{clamped} degenerate pivot(s) clamped"),
        });
    }
    if let Some(d) = exec.last_decision() {
        if !d.error.is_finite() {
            return Err(SelectError::NumericalBreakdown {
                stage: "rank",
                detail: format!("non-finite projection error {}", d.error),
            });
        }
    }
    Ok(())
}

/// The degradation ladder, entered once the configured method has failed
/// under [`FaultPolicy::Degrade`]: feature-only Fast MaxVol first (skipped
/// for deterministic numerical breakdown — MaxVol would break the same
/// way), then a seeded-random subset, which cannot fail.  Each rung taken
/// is recorded in `degr`.
#[allow(clippy::too_many_arguments)]
fn run_ladder(
    cause: SelectError,
    view: &BatchView<'_>,
    r: usize,
    seed: u64,
    window: u64,
    ws: &mut Workspace,
    buf: &mut Vec<usize>,
    degr: &mut Vec<Degradation>,
) -> Result<(), SelectError> {
    if !matches!(cause, SelectError::NumericalBreakdown { .. }) {
        let degen0 = ws.mv_degenerate;
        let ok = catch_unwind(AssertUnwindSafe(|| {
            FastMaxVol.select_into(view, r, ws, buf);
        }))
        .is_ok();
        if ok && ws.mv_degenerate == degen0 {
            degr.push(Degradation::FeatureOnlyMaxVol { cause: cause.to_string() });
            return Ok(());
        }
    }
    // Deterministic in (engine seed, window ordinal): reproducible, but
    // different windows draw different subsets.
    let mut rng = Rng::new(seed ^ (0xDE6 ^ window.wrapping_mul(0x9E37_79B9)));
    buf.clear();
    buf.extend(rng.choose(view.k(), r.min(view.k())));
    degr.push(Degradation::SeededRandom { cause: cause.to_string() });
    Ok(())
}

/// One vectorized pass over the batch looking for non-finite rows
/// (feature row, gradient-sketch row, or loss): per row, one summing fold
/// over the feature and gradient slices — any NaN/±∞ poisons the sum —
/// with an exact cell-wise re-check when the fold trips, since
/// huge-but-finite values can overflow it.  Poisoned row indices land in
/// `out`, ascending.
fn scan_poisoned(view: &BatchView<'_>, out: &mut Vec<usize>) {
    scan_poisoned_range(view, 0..view.k(), out);
}

/// [`scan_poisoned`] restricted to a row range — the streaming engine
/// quarantines per pushed chunk, so it scans only the rows it is about to
/// ingest.  Indices in `out` are view-local (absolute, not
/// range-relative).
pub(crate) fn scan_poisoned_range(
    view: &BatchView<'_>,
    range: std::ops::Range<usize>,
    out: &mut Vec<usize>,
) {
    out.clear();
    let (rc, ec) = (view.features.cols(), view.grads.cols());
    let (fd, gd) = (view.features.data(), view.grads.data());
    for i in range {
        let frow = &fd[i * rc..(i + 1) * rc];
        let grow = &gd[i * ec..(i + 1) * ec];
        let loss = view.losses.get(i).copied().unwrap_or(0.0);
        let acc: f64 = frow.iter().chain(grow.iter()).sum::<f64>() + loss;
        if !acc.is_finite()
            && (!loss.is_finite()
                || frow.iter().chain(grow.iter()).any(|x| !x.is_finite()))
        {
            out.push(i);
        }
    }
}

/// Owned filtered copy of `view` without the `poisoned` rows (ascending),
/// recording each kept row's original index in `kept` (the winner remap
/// table).  Cold path — only runs when something was actually poisoned —
/// so the allocations are irrelevant.
fn filtered_window(
    view: &BatchView<'_>,
    poisoned: &[usize],
    kept: &mut Vec<usize>,
) -> SelectWindow {
    let (rc, ec) = (view.features.cols(), view.grads.cols());
    kept.clear();
    let mut p = 0usize;
    for i in 0..view.k() {
        if p < poisoned.len() && poisoned[p] == i {
            p += 1;
        } else {
            kept.push(i);
        }
    }
    let n = kept.len();
    let mut feat = Vec::with_capacity(n * rc);
    let mut grad = Vec::with_capacity(n * ec);
    let mut win = SelectWindow {
        features: Mat::from_vec(0, rc, Vec::new()),
        grads: Mat::from_vec(0, ec, Vec::new()),
        losses: Vec::with_capacity(n),
        labels: Vec::with_capacity(n),
        preds: Vec::with_capacity(n),
        classes: view.classes,
        row_ids: Vec::with_capacity(n),
    };
    for &i in kept.iter() {
        feat.extend_from_slice(&view.features.data()[i * rc..(i + 1) * rc]);
        grad.extend_from_slice(&view.grads.data()[i * ec..(i + 1) * ec]);
        win.losses.push(view.losses.get(i).copied().unwrap_or(0.0));
        win.labels.push(view.labels.get(i).copied().unwrap_or(0));
        win.preds.push(view.preds.get(i).copied().unwrap_or(0));
        win.row_ids.push(view.row_ids.get(i).copied().unwrap_or(i));
    }
    win.features = Mat::from_vec(n, rc, feat);
    win.grads = Mat::from_vec(n, ec, grad);
    win
}

/// In-place row compaction of an owned [`SelectWindow`]: drop the
/// `poisoned` rows (ascending), shifting everything — including `row_ids`,
/// so the window stays self-consistent for `consume`.  Cold path.
fn quarantine_owned(win: &mut SelectWindow, poisoned: &[usize]) {
    let (rc, ec) = (win.features.cols(), win.grads.cols());
    let k = win.features.rows();
    let mut fv = std::mem::replace(&mut win.features, Mat::from_vec(0, rc, Vec::new())).into_vec();
    let mut gv = std::mem::replace(&mut win.grads, Mat::from_vec(0, ec, Vec::new())).into_vec();
    let (mut w, mut p) = (0usize, 0usize);
    for i in 0..k {
        if p < poisoned.len() && poisoned[p] == i {
            p += 1;
            continue;
        }
        if w != i {
            fv.copy_within(i * rc..(i + 1) * rc, w * rc);
            gv.copy_within(i * ec..(i + 1) * ec, w * ec);
            win.losses[w] = win.losses[i];
            win.labels[w] = win.labels[i];
            win.preds[w] = win.preds[i];
            win.row_ids[w] = win.row_ids[i];
        }
        w += 1;
    }
    fv.truncate(w * rc);
    gv.truncate(w * ec);
    win.losses.truncate(w);
    win.labels.truncate(w);
    win.preds.truncate(w);
    win.row_ids.truncate(w);
    win.features = Mat::from_vec(w, rc, fv);
    win.grads = Mat::from_vec(w, ec, gv);
}

fn resolve_budget(budget: Option<usize>, fraction: f64, k: usize) -> usize {
    budget.unwrap_or_else(|| ((fraction * k as f64).round() as usize).clamp(1, k.max(1)))
}

//! The built engine: hot-path [`SelectionEngine::select`] and the
//! streaming [`SelectionEngine::windows`] session.

use crate::coordinator::{MergePolicy, PooledSelector, SelectWindow, ShardedSelector};
use crate::features::FeatureExtractor;
use crate::graft::{RankDecision, RankStats};
use crate::linalg::Workspace;
use crate::selection::{BatchView, Selector};

use super::builder::ExecShape;

/// The resolved execution backend.  All three are bit-identical for the
/// same method and seed; see [`ExecShape`].
pub(super) enum Exec {
    Serial(Box<dyn Selector>),
    Sharded(Box<ShardedSelector>),
    Pooled(Box<PooledSelector>),
}

impl Exec {
    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        match self {
            Exec::Serial(s) => s.select_into(view, r, ws, out),
            Exec::Sharded(s) => s.select_into(view, r, ws, out),
            Exec::Pooled(p) => p.select_into(view, r, ws, out),
        }
    }

    fn rank_stats(&self) -> Option<RankStats> {
        match self {
            Exec::Serial(s) => s.rank_stats(),
            Exec::Sharded(s) => s.rank_stats(),
            Exec::Pooled(p) => p.rank_stats(),
        }
    }

    fn last_decision(&self) -> Option<RankDecision> {
        match self {
            // The serial decision maker is the selector itself.
            Exec::Serial(s) => s.rank_stats().and_then(|t| t.last),
            // Sharded/pooled: the gradient-merge authority's decision
            // (None under feature-only merges, and for a one-shard pool,
            // whose inner selector lives on a worker thread).
            Exec::Sharded(s) => {
                s.last_rank_decision().or_else(|| s.rank_stats().and_then(|t| t.last))
            }
            Exec::Pooled(p) => p.last_rank_decision(),
        }
    }
}

/// One selection result — the first-class replacement for the per-type
/// side-channel accessors.  Borrows the engine's reused buffer, so
/// holding a `Selection` holds the engine; copy the indices out if you
/// need them across selects.
pub struct Selection<'e> {
    /// Batch-local winner ids (indices into the selected batch's rows),
    /// unique, in selection order.
    pub indices: &'e [usize],
    /// The dynamic-rank decision behind this subset (methods without a
    /// rank stage, feature-only merges, and one-shard pools — whose inner
    /// selector lives on a worker thread — report `None`).
    pub decision: Option<RankDecision>,
    /// The budget this selection was asked for (`min(r, K)` rows come
    /// back for budget-honouring methods).
    pub budget: usize,
    /// 0-based running index of this selection in the engine's lifetime
    /// (windows and one-shot selects share the counter).
    pub window: u64,
}

/// A built selection engine: owns the selector(s) in their execution
/// shape, the scratch [`Workspace`], the result buffer, the validated
/// feature extractor, and the single gradient-merge rank authority.
/// Construct with [`EngineBuilder`](super::EngineBuilder).
pub struct SelectionEngine {
    exec: Exec,
    extractor: Option<Box<dyn FeatureExtractor>>,
    shape: ExecShape,
    merge: MergePolicy,
    fraction: f64,
    budget: Option<usize>,
    ws: Workspace,
    buf: Vec<usize>,
    notes: Vec<String>,
    windows_done: u64,
}

impl SelectionEngine {
    pub(super) fn from_parts(
        exec: Exec,
        extractor: Option<Box<dyn FeatureExtractor>>,
        shape: ExecShape,
        merge: MergePolicy,
        fraction: f64,
        budget: Option<usize>,
        notes: Vec<String>,
    ) -> SelectionEngine {
        SelectionEngine {
            exec,
            extractor,
            shape,
            merge,
            fraction,
            budget,
            ws: Workspace::new(),
            buf: Vec::new(),
            notes,
            windows_done: 0,
        }
    }

    /// The resolved execution shape (after any non-shardable fallback).
    pub fn shape(&self) -> ExecShape {
        self.shape
    }

    /// The resolved merge policy.
    pub fn merge(&self) -> MergePolicy {
        self.merge
    }

    /// Build-time fallback notes (e.g. a non-shardable method downgraded
    /// to serial); empty when the configuration applied as requested.
    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    /// The engine-owned feature extractor, when one was configured.
    pub fn extractor(&self) -> Option<&dyn FeatureExtractor> {
        self.extractor.as_deref()
    }

    /// Per-batch row budget for a K-row batch: the explicit
    /// [`budget`](super::EngineBuilder::budget) if set, else
    /// `round(fraction · K)` clamped to `[1, K]`.
    pub fn budget_for(&self, k: usize) -> usize {
        resolve_budget(self.budget, self.fraction, k)
    }

    /// Dynamic-rank accounting of the single decision maker — the
    /// coordinator's rank authority on sharded/pooled gradient-aware
    /// shapes, or the selector's own policy on the serial path.  `None`
    /// for methods without a rank stage (and for a one-shard pool, whose
    /// inner selector lives on a worker thread).
    pub fn rank_stats(&self) -> Option<RankStats> {
        self.exec.rank_stats()
    }

    /// Decision behind the most recent selection (same caveats as
    /// [`SelectionEngine::rank_stats`]).
    pub fn last_decision(&self) -> Option<RankDecision> {
        self.exec.last_decision()
    }

    /// Select a subset from one batch.  The hot path: scratch and the
    /// result buffer are engine-owned and reused, so steady-state
    /// selection performs no heap allocations (exactly zero for the
    /// MaxVol/GRAFT paths, as pinned by `tests/alloc_free.rs` on the
    /// underlying executors).
    pub fn select(&mut self, view: &BatchView<'_>) -> Selection<'_> {
        let r = resolve_budget(self.budget, self.fraction, view.k());
        self.exec.select_into(view, r, &mut self.ws, &mut self.buf);
        self.windows_done += 1;
        Selection {
            indices: &self.buf,
            decision: self.exec.last_decision(),
            budget: r,
            window: self.windows_done - 1,
        }
    }

    /// Drive `count` selection windows through the engine — the streaming
    /// session that owns the assemble ∥ select overlap pipeline.
    ///
    /// `assemble(w, extractor)` builds window `w` (batch gather, `embed`,
    /// feature extraction — whatever the caller does); the engine passes
    /// its validated extractor in so assembly closures need no selector
    /// knowledge.  `consume(w, window, winners)` receives the batch-local
    /// winner ids for window `w`.
    ///
    /// On a [`ExecShape::Pooled`] shape with `overlap` set, window `w + 1`
    /// is assembled on the calling thread while the pool workers select
    /// window `w`; every other shape runs strictly serial.  The `consume`
    /// stream is identical either way — assembly never depends on
    /// selection results — extending the `run_windows` guarantee pinned by
    /// `tests/selection_pool.rs::overlap_and_serial_paths_agree` to the
    /// facade.  An `Err` from `assemble` aborts the loop after draining
    /// any in-flight selection.
    pub fn windows<E, A, C>(
        &mut self,
        count: usize,
        mut assemble: A,
        mut consume: C,
    ) -> Result<(), E>
    where
        // Named generics (not impl-Trait arguments) so callers whose
        // error type is not pinned by inference can turbofish it:
        // `eng.windows::<anyhow::Error, _, _>(...)`.
        A: FnMut(usize, Option<&dyn FeatureExtractor>) -> Result<SelectWindow, E>,
        C: FnMut(usize, &SelectWindow, &[usize]),
    {
        if count == 0 {
            return Ok(());
        }
        let SelectionEngine {
            exec, extractor, shape, fraction, budget, ws, buf, windows_done, ..
        } = self;
        let ext = extractor.as_deref();
        if let Exec::Pooled(pool) = exec {
            // Both pooled modes run through the coordinator's single
            // overlap-pipeline implementation (`run_windows_with`), so the
            // subtle begin / assemble-next / finish drain-on-error
            // ordering lives in exactly one place.
            let overlap = matches!(shape, ExecShape::Pooled { overlap: true, .. });
            return crate::coordinator::pool::run_windows_with(
                pool,
                |k| resolve_budget(*budget, *fraction, k),
                overlap,
                count,
                ws,
                buf,
                |wi| assemble(wi, ext),
                |wi, win, winners| {
                    *windows_done += 1;
                    consume(wi, win, winners);
                },
            );
        }
        for wi in 0..count {
            let win = assemble(wi, ext)?;
            let view = win.view();
            let r = resolve_budget(*budget, *fraction, view.k());
            exec.select_into(&view, r, ws, buf);
            *windows_done += 1;
            consume(wi, &win, buf);
        }
        Ok(())
    }

    /// Tear down pooled workers now (otherwise on drop; idempotent; a
    /// no-op for non-pooled shapes).
    pub fn shutdown(&mut self) {
        if let Exec::Pooled(p) = &mut self.exec {
            p.shutdown();
        }
    }
}

fn resolve_budget(budget: Option<usize>, fraction: f64, k: usize) -> usize {
    budget.unwrap_or_else(|| ((fraction * k as f64).round() as usize).clamp(1, k.max(1)))
}

//! `StreamingEngine` — the bounded-memory streaming session of the
//! selection facade.
//!
//! Where [`SelectionEngine`](super::SelectionEngine) selects from one
//! fully-assembled batch at a time, the streaming engine ingests rows
//! **in chunks of any size** ([`StreamingEngine::push`] /
//! [`StreamingEngine::push_range`]) and can be asked for a selection at
//! any point ([`StreamingEngine::snapshot`]).  Memory stays
//! O(cap·(R+E)) with `cap = max(2·budget, R)` no matter how long the
//! stream runs — the reservoir and its incremental-MaxVol admission live
//! in [`crate::coordinator::stream`].
//!
//! Guarantees (pinned by `tests/streaming.rs`):
//!
//! * **Stream ≡ batch.**  When the whole stream fits the reservoir
//!   (K ≤ cap), a snapshot is bit-identical to the batch selector on the
//!   same rows — strict and adaptive rank alike — because the snapshot
//!   *is* the batch pipeline run over the residents.
//! * **Chunk-oblivious.**  Rows are processed one at a time internally,
//!   so any chunking of the same arrival order yields identical state
//!   and identical snapshots, for streams of any length.
//! * **Typed faults, no panics.**  Non-finite rows in a pushed chunk are
//!   rejected atomically with [`SelectError::PoisonedInput`] under
//!   `Fail`/`Retry` (nothing from the chunk is ingested), or skipped and
//!   recorded as [`Degradation::Quarantined`] under `Degrade`.
//!   Degenerate MaxVol pivots surface at the next snapshot as
//!   [`SelectError::NumericalBreakdown`] — or, under `Degrade`, the
//!   snapshot falls back to the same seeded-random rung as the batch
//!   ladder (recorded as [`Degradation::SeededRandom`]).
//!
//! Built by [`EngineBuilder::build_streaming`](super::EngineBuilder::build_streaming);
//! streaming requires an explicit row budget (a fraction of an unknown
//! stream length is meaningless) and a MaxVol-criterion method (`graft`,
//! `graft-warm`, `maxvol`) whose selection survives incremental
//! maintenance.

use crate::coordinator::fault::{Degradation, FaultPolicy, SelectError};
use crate::coordinator::stream::StreamState;
use crate::features::FeatureExtractor;
use crate::graft::{BudgetedRankPolicy, RankDecision, RankStats, StrictRankTally};
use crate::linalg::Workspace;
use crate::rng::Rng;
use crate::selection::BatchView;

use super::select::scan_poisoned_range;

/// One materialised selection from a stream: the streaming counterpart of
/// [`Selection`](super::Selection), owned rather than borrowed because a
/// snapshot outlives no engine buffer.
#[derive(Debug)]
pub struct StreamSnapshot {
    /// Selected **global row ids** (the `row_ids` of the pushed views),
    /// in selection order: MaxVol pivots first, then the loss top-up.
    pub indices: Vec<usize>,
    /// The rank decision for a GRAFT stream that was not degraded
    /// (`None` for feature-only `maxvol` streams, empty streams, and
    /// seeded-random fallbacks).  Adaptive streams report the rank
    /// authority's decision; strict streams synthesise the equivalent
    /// decision from the engine's strict tally (the strict cut is the
    /// identity, so no authority — and no gradient carry — runs).
    pub decision: Option<RankDecision>,
    /// The configured per-snapshot row budget.
    pub budget: usize,
    /// Total rows streamed in so far (resident or evicted).
    pub rows_seen: u64,
    /// Rows currently resident in the reservoir.
    pub reservoir_len: usize,
    /// Degradations recorded since the previous snapshot (quarantined
    /// chunks, seeded-random fallback); empty on a healthy stream.
    pub degradations: Vec<Degradation>,
}

/// Streaming selection session — see the [module docs](self).
pub struct StreamingEngine {
    state: StreamState,
    /// GRAFT rank authority (one accumulator for the whole stream, like
    /// the batch engine's); `None` runs feature-only MaxVol.
    policy: Option<BudgetedRankPolicy>,
    top_up: bool,
    budget: usize,
    fault: FaultPolicy,
    seed: u64,
    extractor: Option<Box<dyn FeatureExtractor>>,
    notes: Vec<String>,
    ws: Workspace,
    qrows: Vec<usize>,
    degr: Vec<Degradation>,
    quarantined: u64,
    /// Degenerate pivots clamped during pushes since the last snapshot
    /// (admission tournaments); folded into the snapshot's health check.
    push_degenerate: u64,
    snapshots: u64,
    last: Option<RankDecision>,
    /// Strict-rank accounting for GRAFT streams without a rank authority
    /// (the adaptive-only carry; see
    /// [`SelectionEngine`](super::SelectionEngine)'s field of the same
    /// name).  Survives [`StreamingEngine::reset`], like the adaptive
    /// authority's accumulator.
    strict_tally: Option<StrictRankTally>,
}

impl StreamingEngine {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        policy: Option<BudgetedRankPolicy>,
        top_up: bool,
        budget: usize,
        fault: FaultPolicy,
        seed: u64,
        extractor: Option<Box<dyn FeatureExtractor>>,
        strict_tally: Option<StrictRankTally>,
        sketch_f32: bool,
        notes: Vec<String>,
    ) -> StreamingEngine {
        let mut state = StreamState::new(budget);
        // Only an adaptive rank authority reads gradient sketches at
        // snapshot time; strict and feature-only streams skip the carry
        // entirely (zero resident sketch bytes).
        state.set_carry(policy.is_some());
        state.set_sketch_f32(sketch_f32);
        StreamingEngine {
            state,
            policy,
            top_up,
            budget,
            fault,
            seed,
            extractor,
            notes,
            ws: Workspace::default(),
            qrows: Vec::new(),
            degr: Vec::new(),
            quarantined: 0,
            push_degenerate: 0,
            snapshots: 0,
            last: None,
            strict_tally,
        }
    }

    /// Ingest every row of `view`.  Equivalent to
    /// [`StreamingEngine::push_range`] over `0..view.k()`.
    pub fn push(&mut self, view: &BatchView<'_>) -> Result<(), SelectError> {
        self.push_range(view, 0..view.k())
    }

    /// Ingest rows `range` of `view` (the chunk boundary is invisible to
    /// the result: any chunking of the same row order is equivalent).
    ///
    /// All pushed views of one stream must share the feature/sketch
    /// widths of the first (a shape change is a caller contract
    /// violation).  Non-finite rows fault per the configured policy —
    /// under `Fail`/`Retry` the chunk is rejected atomically with
    /// [`SelectError::PoisonedInput`] (view-local row indices) and
    /// nothing is ingested; under `Degrade` the poisoned rows are
    /// skipped and recorded, and the clean remainder streams in.
    pub fn push_range(
        &mut self,
        view: &BatchView<'_>,
        range: std::ops::Range<usize>,
    ) -> Result<(), SelectError> {
        assert!(range.end <= view.k(), "push range {range:?} exceeds view rows {}", view.k());
        scan_poisoned_range(view, range.clone(), &mut self.qrows);
        if !self.qrows.is_empty() {
            if !matches!(self.fault, FaultPolicy::Degrade) {
                return Err(SelectError::PoisonedInput { rows: self.qrows.clone() });
            }
            self.quarantined += self.qrows.len() as u64;
            self.degr.push(Degradation::Quarantined { rows: self.qrows.clone() });
        }
        let degen0 = self.ws.mv_degenerate;
        let mut q = 0usize;
        for i in range {
            if q < self.qrows.len() && self.qrows[q] == i {
                q += 1;
                continue;
            }
            self.state.push_row(
                view.features.row(i),
                view.grads.row(i),
                view.losses[i],
                view.row_ids[i],
                &mut self.ws,
            );
        }
        self.push_degenerate += self.ws.mv_degenerate - degen0;
        Ok(())
    }

    /// Select from everything streamed so far.  Does not perturb the
    /// stream: pushing may continue afterwards, and each snapshot
    /// advances the rank authority's budget accounting exactly once
    /// (like one batch select).
    ///
    /// Numerical breakdown (degenerate pivots in any tournament since
    /// the last snapshot, or a non-finite rank decision) surfaces here:
    /// typed error under `Fail`/`Retry` (deterministic — a retry cannot
    /// help), seeded-random fallback under `Degrade`.
    pub fn snapshot(&mut self) -> Result<StreamSnapshot, SelectError> {
        let window = self.snapshots;
        self.snapshots += 1;
        let degen0 = self.ws.mv_degenerate;
        let mut out = Vec::new();
        let decision =
            self.state.snapshot_into(self.policy.as_mut(), self.top_up, &mut self.ws, &mut out);
        let clamped = self.push_degenerate + (self.ws.mv_degenerate - degen0);
        self.push_degenerate = 0;
        let bad_rank = decision.is_some_and(|d| !d.error.is_finite());
        if clamped > 0 || bad_rank {
            let cause = if clamped > 0 {
                SelectError::NumericalBreakdown {
                    stage: "stream-maxvol",
                    detail: format!("{clamped} degenerate pivot(s) clamped in the streaming reservoir"),
                }
            } else {
                SelectError::NumericalBreakdown {
                    stage: "rank",
                    detail: format!(
                        "non-finite projection error {}",
                        decision.map(|d| d.error).unwrap_or(f64::NAN)
                    ),
                }
            };
            if !matches!(self.fault, FaultPolicy::Degrade) {
                return Err(cause);
            }
            // Deterministic breakdown skips straight to the seeded-random
            // rung, exactly like the batch ladder (same seed formula, the
            // snapshot ordinal standing in for the window ordinal).
            let len = self.state.len();
            let mut rng = Rng::new(self.seed ^ (0xDE6 ^ window.wrapping_mul(0x9E37_79B9)));
            out.clear();
            out.extend(rng.choose(len, self.budget.min(len)).into_iter().map(|i| self.state.id_at(i)));
            self.degr.push(Degradation::SeededRandom { cause: cause.to_string() });
            self.last = None;
            return Ok(self.finish(out, None));
        }
        // Strict GRAFT streams carry no rank authority; synthesise the
        // decision the authority's identity cut would have made from the
        // reservoir's strict rank (see `StreamState::strict_rank`).
        let decision = decision.or_else(|| {
            let rank = self.state.strict_rank();
            match self.strict_tally.as_mut() {
                Some(t) if !out.is_empty() => Some(t.record(rank)),
                _ => None,
            }
        });
        self.last = decision;
        Ok(self.finish(out, decision))
    }

    fn finish(&mut self, indices: Vec<usize>, decision: Option<RankDecision>) -> StreamSnapshot {
        StreamSnapshot {
            indices,
            decision,
            budget: self.budget,
            rows_seen: self.state.rows_seen(),
            reservoir_len: self.state.len(),
            degradations: std::mem::take(&mut self.degr),
        }
    }

    /// Start a fresh stream, keeping the engine: the reservoir empties
    /// (buffer capacity is retained, so the next stream allocates
    /// nothing) while the rank authority's run-level budget accounting
    /// carries over — one accumulator per engine, like the batch facade.
    pub fn reset(&mut self) {
        self.state.reset();
        self.degr.clear();
        self.push_degenerate = 0;
    }

    /// Configured per-snapshot row budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Total rows streamed into the current stream.
    pub fn rows_seen(&self) -> u64 {
        self.state.rows_seen()
    }

    /// Rows currently resident in the reservoir.
    pub fn reservoir_len(&self) -> usize {
        self.state.len()
    }

    /// Resident-row bound (0 until the first push fixes the dimensions).
    pub fn reservoir_capacity(&self) -> usize {
        self.state.capacity()
    }

    /// Total poisoned rows quarantined over the engine's lifetime
    /// (only grows under [`FaultPolicy::Degrade`]).
    pub fn quarantined_rows(&self) -> u64 {
        self.quarantined
    }

    /// Validated extractor owned by the engine (for callers assembling
    /// their own chunks, mirroring [`SelectionEngine::extractor`]).
    ///
    /// [`SelectionEngine::extractor`]: super::SelectionEngine::extractor
    pub fn extractor(&self) -> Option<&dyn FeatureExtractor> {
        self.extractor.as_deref()
    }

    /// Build-time fallback notes (e.g. a non-serial shape request).
    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    /// Rank-authority accounting (`None` for feature-only streams).
    /// Strict GRAFT streams report the engine's strict tally — see
    /// [`StreamSnapshot::decision`].
    pub fn rank_stats(&self) -> Option<RankStats> {
        self.policy
            .as_ref()
            .map(|p| RankStats { mean_rank: p.mean_rank(), batches: p.batches(), last: self.last })
            .or_else(|| self.strict_tally.as_ref().map(|t| t.stats()))
    }

    /// Bytes of gradient-sketch columns resident in the reservoir (zero
    /// for strict and feature-only streams under the adaptive-only
    /// carry).  Test/bench telemetry, not a stable API.
    #[doc(hidden)]
    pub fn carried_sketch_bytes(&self) -> usize {
        self.state.sketch_bytes()
    }
}

//! Typed construction and validation for [`SelectionEngine`].
//!
//! Every cross-knob rule that used to be split between the CLI defaults
//! (`config::Args::train_config`), `TrainConfig::default`, and the
//! trainer's hand-wiring lives in [`EngineBuilder::build`]: it is the one
//! place that decides what a valid selection configuration *is*, what the
//! method-aware defaults are, and which requested shapes fall back (with a
//! note) instead of erroring.

use std::time::Duration;

use crate::coordinator::{FaultPolicy, MergePolicy, PooledSelector, ShardedSelector};
use crate::features::{self, FeatureExtractor};
use crate::graft::{BudgetedRankPolicy, GraftSelector, StrictRankTally};
use crate::selection::{self, Selector};
use crate::train::TrainConfig;

use super::select::{Exec, SelectionEngine};
use super::stream::StreamingEngine;

/// How selection executes, spatially: the typed replacement for the
/// `shards` / `pool_workers` / `overlap` knob pile.  All shapes are
/// bit-identical for the same method and seed (pinned by
/// `tests/engine_api.rs` through the facade, and by the coordinator
/// suites underneath); they differ only in where the work runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecShape {
    /// One selector, inline on the calling thread.
    Serial,
    /// Fan each batch across `shards` worker shards on per-call scoped
    /// threads, merging winners with the configured [`MergePolicy`].
    Sharded {
        /// Number of selection shards (≥ 1; 1 collapses to [`Serial`]).
        ///
        /// [`Serial`]: ExecShape::Serial
        shards: usize,
    },
    /// Route shard jobs through a persistent
    /// [`SelectionPool`](crate::coordinator::pool::SelectionPool) of
    /// long-lived workers.  The only shape that can overlap next-window
    /// assembly with in-flight selection — which is why `overlap` lives
    /// *inside* this variant: "overlap without a pool" is unrepresentable
    /// in the typed API (the knob path rejects it with
    /// [`EngineError::OverlapWithoutPool`]).
    Pooled {
        /// Number of selection shards dealt across the workers (≥ 1).
        shards: usize,
        /// Pool worker threads (≥ 1; clamped to `shards` at spawn).
        workers: usize,
        /// Pipeline `assemble(w + 1)` against the in-flight selection of
        /// window `w` in [`SelectionEngine::windows`].  Selections are
        /// identical with the flag on or off; only wall-clock changes.
        overlap: bool,
    },
}

impl ExecShape {
    /// Resolve the legacy knob triple (`--shards`, `--pool-workers`,
    /// `--overlap`) into a typed shape.  This is the ONE decision table
    /// for the knob semantics:
    ///
    /// * `overlap` without a pool → [`EngineError::OverlapWithoutPool`]
    /// * `shards == 0` → [`EngineError::ZeroShards`]
    /// * `pool_workers >= 1` → [`ExecShape::Pooled`] (any shard count —
    ///   a one-shard pool hosts the selector off-thread with no merge)
    /// * `shards > 1` → [`ExecShape::Sharded`]
    /// * otherwise → [`ExecShape::Serial`]
    pub fn from_knobs(
        shards: usize,
        pool_workers: usize,
        overlap: bool,
    ) -> Result<ExecShape, EngineError> {
        if overlap && pool_workers == 0 {
            return Err(EngineError::OverlapWithoutPool);
        }
        if shards == 0 {
            return Err(EngineError::ZeroShards);
        }
        Ok(if pool_workers >= 1 {
            ExecShape::Pooled { shards, workers: pool_workers, overlap }
        } else if shards > 1 {
            ExecShape::Sharded { shards }
        } else {
            ExecShape::Serial
        })
    }

    /// Validate a shape built directly (typed path).
    fn validate(self) -> Result<ExecShape, EngineError> {
        match self {
            ExecShape::Sharded { shards: 0 } | ExecShape::Pooled { shards: 0, .. } => {
                Err(EngineError::ZeroShards)
            }
            ExecShape::Pooled { workers: 0, .. } => Err(EngineError::ZeroWorkers),
            s => Ok(s),
        }
    }

    /// Shard count of the shape (1 for serial).
    pub fn shards(self) -> usize {
        match self {
            ExecShape::Serial => 1,
            ExecShape::Sharded { shards } | ExecShape::Pooled { shards, .. } => shards,
        }
    }
}

/// How the merged/selected pivot order is arranged before the rank cut
/// (GRAFT methods only; other methods have no pivot stage to re-order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PivotMode {
    /// Feature-volume order: the Fast MaxVol pivot sequence as-is (the
    /// paper's Stage 1 and the historical behaviour).
    #[default]
    FeatureVol,
    /// Gradient-aware order: MaxVol still fixes winner *membership*, but
    /// the order the rank cut truncates is greedily re-sorted by residual
    /// ‖ĝ‖ coverage (`graft::geometry::grad_aware_order`), so a given
    /// budget keeps the prefix that best approximates the batch-mean
    /// gradient.  With zero gradient signal the feature order is kept bit
    /// for bit.  At `shards > 1` this requires the gradient-aware merge
    /// ([`EngineError::PivotNeedsGradMerge`] otherwise); non-GRAFT methods
    /// are rejected with [`EngineError::PivotNeedsGraft`].
    GradAware,
}

/// How the subset size per batch is decided (GRAFT's Stage 2; ignored by
/// methods without a rank stage).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RankMode {
    /// Exactly the requested budget every batch (fractions comparable
    /// across methods — the sweep/comparison harness mode).  The
    /// builder's [`EngineBuilder::epsilon`] is still recorded in each
    /// [`RankDecision`](crate::graft::RankDecision) for telemetry.
    Strict,
    /// Dynamic rank: the smallest R* whose projection error meets ε,
    /// under the running fraction budget (paper §3.2, Alg. 1).  On
    /// sharded/pooled shapes this requires the gradient-aware merge to
    /// take effect (the builder notes the mismatch otherwise).
    Adaptive {
        /// Projection-error threshold ε ∈ (0, 1].
        epsilon: f64,
    },
}

/// A rejected builder configuration.  Every variant names the offending
/// field — both in the type ([`EngineError::field`]) and in the Display
/// message — so callers can surface precise errors without string
/// matching.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// `method`: not a known selection method.
    UnknownMethod { method: String },
    /// `extractor`: not a known feature extractor.
    UnknownExtractor { extractor: String },
    /// `merge`: not a known merge policy spelling.
    UnknownMerge { merge: String },
    /// `shards`: zero shards requested.
    ZeroShards,
    /// `workers`: a pooled shape with zero workers.
    ZeroWorkers,
    /// `overlap`: overlap requested without a worker pool.
    OverlapWithoutPool,
    /// `epsilon`: ε outside (0, 1] or not finite.
    EpsilonOutOfRange { epsilon: f64 },
    /// `fraction`: data fraction outside (0, 1] or not finite.
    FractionOutOfRange { fraction: f64 },
    /// `budget`: an explicit per-batch budget of zero rows.
    ZeroBudget,
    /// `budget`: a streaming session without an explicit row budget (the
    /// reservoir bound is `2·budget`, and a fraction of an unknown stream
    /// length cannot size it).
    StreamNeedsBudget,
    /// `method`: a known selection method whose criterion does not
    /// survive incremental reservoir maintenance (streaming supports the
    /// MaxVol family: `graft`, `graft-warm`, `maxvol`, `fast-maxvol`).
    StreamUnsupportedMethod { method: String },
    /// `pivot`: [`PivotMode::GradAware`] requested for a method without a
    /// gradient-aware pivot stage (only GRAFT methods have one).
    PivotNeedsGraft { method: String },
    /// `pivot`: [`PivotMode::GradAware`] at `shards > 1` with a merge
    /// policy that carries no gradient context across the shard boundary
    /// (the pivot stage re-orders at the merge, so it needs `merge grad`).
    PivotNeedsGradMerge { merge: String },
    /// `explore`: hybrid explore fraction outside [0, 1] or not finite.
    ExploreOutOfRange { explore: f64 },
}

impl EngineError {
    /// Name of the builder field the error is about.
    pub fn field(&self) -> &'static str {
        match self {
            EngineError::UnknownMethod { .. } => "method",
            EngineError::UnknownExtractor { .. } => "extractor",
            EngineError::UnknownMerge { .. } => "merge",
            EngineError::ZeroShards => "shards",
            EngineError::ZeroWorkers => "workers",
            EngineError::OverlapWithoutPool => "overlap",
            EngineError::EpsilonOutOfRange { .. } => "epsilon",
            EngineError::FractionOutOfRange { .. } => "fraction",
            EngineError::ZeroBudget => "budget",
            EngineError::StreamNeedsBudget => "budget",
            EngineError::StreamUnsupportedMethod { .. } => "method",
            EngineError::PivotNeedsGraft { .. } => "pivot",
            EngineError::PivotNeedsGradMerge { .. } => "pivot",
            EngineError::ExploreOutOfRange { .. } => "explore",
        }
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnknownMethod { method } => {
                write!(f, "method: unknown selection method '{method}'")
            }
            EngineError::UnknownExtractor { extractor } => {
                write!(f, "extractor: unknown feature extractor '{extractor}' (svd|pca|ica|ae)")
            }
            EngineError::UnknownMerge { merge } => {
                write!(f, "merge: unknown merge policy '{merge}' (hierarchical|flat|grad)")
            }
            EngineError::ZeroShards => write!(f, "shards: must be at least 1"),
            EngineError::ZeroWorkers => {
                write!(f, "workers: a pooled shape needs at least 1 worker")
            }
            EngineError::OverlapWithoutPool => {
                write!(f, "overlap: requires a persistent worker pool (ExecShape::Pooled)")
            }
            EngineError::EpsilonOutOfRange { epsilon } => {
                write!(f, "epsilon: {epsilon} outside the valid range (0, 1]")
            }
            EngineError::FractionOutOfRange { fraction } => {
                write!(f, "fraction: {fraction} outside the valid range (0, 1]")
            }
            EngineError::ZeroBudget => write!(f, "budget: must be at least 1 row"),
            EngineError::StreamNeedsBudget => write!(
                f,
                "budget: streaming needs an explicit row budget (EngineBuilder::budget) — a \
                 fraction of an unknown stream length cannot size the reservoir"
            ),
            EngineError::StreamUnsupportedMethod { method } => write!(
                f,
                "method: '{method}' cannot stream (its criterion does not survive incremental \
                 reservoir maintenance); streaming supports graft|graft-warm|maxvol|fast-maxvol"
            ),
            EngineError::PivotNeedsGraft { method } => write!(
                f,
                "pivot: gradient-aware pivot ordering re-orders GRAFT's rank-cut prefix; \
                 method '{method}' has no pivot stage (use graft|graft-warm)"
            ),
            EngineError::PivotNeedsGradMerge { merge } => write!(
                f,
                "pivot: gradient-aware pivot at shards > 1 re-orders at the merge, which \
                 needs the gradient context of the grad merge; merge '{merge}' carries none"
            ),
            EngineError::ExploreOutOfRange { explore } => {
                write!(f, "explore: {explore} outside the valid range [0, 1]")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// The method-aware merge default, in one place (previously duplicated by
/// the CLI and `TrainConfig::default`): GRAFT merges gradient-aware —
/// that is the paper's criterion, and feature-only merging silently
/// degrades it at `shards > 1` — while every other method keeps the
/// feature-space hierarchical tournament.
pub fn default_merge(method: &str) -> MergePolicy {
    if method.starts_with("graft") {
        MergePolicy::Grad
    } else {
        MergePolicy::Hierarchical
    }
}

/// The exact GRAFT method spellings the engine builds a [`GraftSelector`]
/// for.  Deliberately NOT a `starts_with("graft")` prefix test: a typo
/// like `graftx` must fail [`EngineBuilder::build`] with
/// [`EngineError::UnknownMethod`] rather than silently selecting with a
/// default GRAFT configuration.
fn is_graft_method(method: &str) -> bool {
    matches!(method, "graft" | "graft-warm")
}

/// Where the execution shape comes from: the typed setter or the legacy
/// knob triple (resolved by [`ExecShape::from_knobs`] at build time).
#[derive(Debug, Clone)]
enum ShapeSpec {
    Knobs { shards: usize, pool_workers: usize, overlap: bool },
    Typed(ExecShape),
}

/// Merge policy request: typed, by CLI spelling, or the method-aware
/// default.
#[derive(Debug, Clone)]
enum MergeSpec {
    Default,
    Policy(MergePolicy),
    Named(String),
}

/// Builder for a [`SelectionEngine`] — see the [module docs](crate::engine)
/// for the full picture.  All setters are infallible; [`EngineBuilder::build`]
/// validates everything at once and returns the first violated rule as a
/// typed [`EngineError`].
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    method: String,
    seed: u64,
    fraction: f64,
    budget: Option<usize>,
    epsilon: f64,
    rank: RankMode,
    extractor: Option<String>,
    merge: MergeSpec,
    shape: ShapeSpec,
    fault: FaultPolicy,
    deadline: Option<Duration>,
    sketch_f32: bool,
    pivot: PivotMode,
    explore: Option<f64>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder::new()
    }
}

impl EngineBuilder {
    /// Start from the defaults: GRAFT, fraction 0.25, ε = 0.1, strict
    /// rank, serial execution, method-aware merge, seed 42.
    pub fn new() -> EngineBuilder {
        EngineBuilder {
            method: "graft".to_string(),
            seed: 42,
            fraction: 0.25,
            budget: None,
            epsilon: 0.1,
            rank: RankMode::Strict,
            extractor: None,
            merge: MergeSpec::Default,
            shape: ShapeSpec::Knobs { shards: 1, pool_workers: 0, overlap: false },
            fault: FaultPolicy::Fail,
            deadline: None,
            sketch_f32: false,
            pivot: PivotMode::FeatureVol,
            explore: None,
        }
    }

    /// Selection method: `graft`, `graft-warm`, or any
    /// [`selection::by_name`] baseline (`maxvol`, `cross-maxvol`,
    /// `random`, `craig`, …).
    pub fn method(mut self, method: impl Into<String>) -> Self {
        self.method = method.into();
        self
    }

    /// Base RNG seed for seeded methods.  Shard `i` derives its instance
    /// seed as `seed ^ i·φ64` (shard 0 keeps the base seed, so every
    /// shape matches the serial construction).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Target data fraction f ∈ (0, 1]: the per-batch budget is
    /// `round(f·K)` unless [`EngineBuilder::budget`] pins an absolute
    /// size, and the adaptive rank policy averages toward it.
    pub fn fraction(mut self, fraction: f64) -> Self {
        self.fraction = fraction;
        self
    }

    /// Fixed per-batch budget in rows (overrides the fraction-derived
    /// size; the adaptive policy still averages toward `fraction`).
    pub fn budget(mut self, rows: usize) -> Self {
        self.budget = Some(rows);
        self
    }

    /// Projection-error threshold ε recorded by strict-mode decisions
    /// (the criterion threshold in adaptive mode travels inside
    /// [`RankMode::Adaptive`]).
    pub fn epsilon(mut self, epsilon: f64) -> Self {
        self.epsilon = epsilon;
        self
    }

    /// How the subset size is decided per batch (GRAFT only).
    pub fn rank(mut self, rank: RankMode) -> Self {
        self.rank = rank;
        self
    }

    /// Rust-side feature extractor (`svd` | `pca` | `ica` | `ae`): the
    /// built engine owns the validated extractor and hands it to
    /// [`SelectionEngine::windows`] assembly closures (also readable via
    /// [`SelectionEngine::extractor`]).
    pub fn extractor(mut self, name: impl Into<String>) -> Self {
        self.extractor = Some(name.into());
        self
    }

    /// Merge policy for sharded shapes (typed).  Unset = method-aware
    /// default ([`default_merge`]).
    pub fn merge(mut self, merge: MergePolicy) -> Self {
        self.merge = MergeSpec::Policy(merge);
        self
    }

    /// Merge policy by CLI spelling (`hierarchical` | `flat` | `grad`);
    /// unknown spellings fail `build()` with [`EngineError::UnknownMerge`].
    pub fn merge_name(mut self, name: impl Into<String>) -> Self {
        self.merge = MergeSpec::Named(name.into());
        self
    }

    /// Typed execution shape.  Overrides any previously set knobs; later
    /// knob setters decompose it back into knob form.
    pub fn exec(mut self, shape: ExecShape) -> Self {
        self.shape = ShapeSpec::Typed(shape);
        self
    }

    /// What the engine does when selection faults (worker panic, poisoned
    /// input, numerical breakdown): surface the typed
    /// [`SelectError`](crate::engine::SelectError) (the
    /// [`FaultPolicy::Fail`] default), respawn-and-retry within a budget
    /// ([`FaultPolicy::Retry`] — a successful retry is bit-identical to
    /// the fault-free run), or walk the degradation ladder
    /// ([`FaultPolicy::Degrade`]: GRAFT → feature-only MaxVol →
    /// seeded-random, every rung recorded on the
    /// [`Selection`](crate::engine::Selection)).  Zero-fault results are
    /// bit-identical under every policy.
    pub fn fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault = policy;
        self
    }

    /// Per-job deadline on pooled shapes before the coordinator probes
    /// worker health and requeues wedged shards (default 30 s; ignored by
    /// serial/sharded shapes, whose shard work runs on the caller's
    /// thread).
    pub fn job_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Carry gradient sketches across the shard/worker → merge boundary
    /// (and in the streaming reservoir) narrowed to f32: half the
    /// boundary bandwidth and resident sketch memory.  Off by default —
    /// the f64 carry is bitwise the source rows.  The merged pivot order
    /// is computed on f64 features either way, so narrowing can only move
    /// the adaptive rank cut, never reorder winners (tolerance-pinned by
    /// `tests/sketch_f32.rs`).  Inert on serial shapes and in strict rank
    /// mode, where no sketches are carried at all.
    pub fn sketch_f32(mut self, on: bool) -> Self {
        self.sketch_f32 = on;
        self
    }

    /// How the rank-cut prefix is ordered (GRAFT methods only; see
    /// [`PivotMode`]).  [`PivotMode::GradAware`] with a non-GRAFT method
    /// fails `build()` with [`EngineError::PivotNeedsGraft`]; at
    /// `shards > 1` it additionally requires the gradient-aware merge
    /// ([`EngineError::PivotNeedsGradMerge`]).  Streaming sessions keep
    /// the feature order with a note (reservoir maintenance is
    /// incremental; there is no merged union to re-sort).
    pub fn pivot(mut self, pivot: PivotMode) -> Self {
        self.pivot = pivot;
        self
    }

    /// Explore fraction φ ∈ [0, 1] for the `hybrid` method: the seeded
    /// random share mixed into the MaxVol subset
    /// ([`selection::hybrid::Hybrid`]).  φ = 0 is pure Fast MaxVol bit
    /// for bit; φ = 1 is the seeded-random baseline bit for bit.  Unset
    /// = [`selection::hybrid::DEFAULT_EXPLORE`]; out-of-range values
    /// fail `build()` with [`EngineError::ExploreOutOfRange`].  Inert
    /// (with a note) for every other method.
    pub fn explore_fraction(mut self, explore: f64) -> Self {
        self.explore = Some(explore);
        self
    }

    /// Legacy knob: shard count (`--shards`).
    pub fn shards(mut self, shards: usize) -> Self {
        let (_, pool_workers, overlap) = self.knobs();
        self.shape = ShapeSpec::Knobs { shards, pool_workers, overlap };
        self
    }

    /// Legacy knob: persistent pool workers (`--pool-workers`; 0 = no
    /// pool, scoped-thread fan-out).
    pub fn pool_workers(mut self, workers: usize) -> Self {
        let (shards, _, overlap) = self.knobs();
        self.shape = ShapeSpec::Knobs { shards, pool_workers: workers, overlap };
        self
    }

    /// Legacy knob: overlap assembly with in-flight selection
    /// (`--overlap`; needs a pool).
    pub fn overlap(mut self, overlap: bool) -> Self {
        let (shards, pool_workers, _) = self.knobs();
        self.shape = ShapeSpec::Knobs { shards, pool_workers, overlap };
        self
    }

    fn knobs(&self) -> (usize, usize, bool) {
        match &self.shape {
            ShapeSpec::Knobs { shards, pool_workers, overlap } => {
                (*shards, *pool_workers, *overlap)
            }
            ShapeSpec::Typed(ExecShape::Serial) => (1, 0, false),
            ShapeSpec::Typed(ExecShape::Sharded { shards }) => (*shards, 0, false),
            ShapeSpec::Typed(ExecShape::Pooled { shards, workers, overlap }) => {
                (*shards, *workers, *overlap)
            }
        }
    }

    /// Map a [`TrainConfig`]'s selection knobs onto the builder.  This is
    /// the compatibility path for the CLI/trainer and it preserves the
    /// historical *fallback* semantics where the typed API rejects:
    /// `overlap` without a pool is dropped here (the trainer prints the
    /// run-level note, since the rule also concerns the AOT path that
    /// never builds an engine) and `shards == 0` is clamped to serial.
    /// Rank-stage knobs (`epsilon`, `adaptive_rank`) and the extractor are
    /// GRAFT-path settings: baselines never consulted them pre-engine, so
    /// they are not mapped — and therefore not validated — for baseline
    /// methods (`--method el2n --epsilon 2.0` keeps running, exactly as it
    /// always did; the typed builder path still rejects it).
    pub fn from_train_config(cfg: &TrainConfig) -> EngineBuilder {
        let mut b = EngineBuilder::new()
            .method(&cfg.method)
            .seed(cfg.seed ^ 0xBA5E)
            .fraction(cfg.fraction)
            .merge(cfg.merge)
            .shards(cfg.shards.max(1))
            .pool_workers(cfg.pool_workers)
            .overlap(cfg.overlap && cfg.pool_workers >= 1);
        if is_graft_method(&cfg.method) {
            b = b.epsilon(cfg.epsilon);
            if cfg.adaptive_rank {
                b = b.rank(RankMode::Adaptive { epsilon: cfg.epsilon });
            }
            if let Some(ext) = &cfg.extractor {
                b = b.extractor(ext);
            }
        }
        b
    }

    /// Validate the whole configuration and construct the engine.  The
    /// first violated rule is returned as a typed [`EngineError`];
    /// *requested-but-inapplicable* shapes (sharding a non-shardable
    /// method) fall back with a note instead — readable afterwards via
    /// [`SelectionEngine::notes`], and echoed to stderr like the
    /// pre-engine trainer did.
    pub fn build(self) -> Result<SelectionEngine, EngineError> {
        // -- scalar knobs ------------------------------------------------
        if !self.fraction.is_finite() || self.fraction <= 0.0 || self.fraction > 1.0 {
            return Err(EngineError::FractionOutOfRange { fraction: self.fraction });
        }
        let check_eps = |epsilon: f64| {
            if !epsilon.is_finite() || epsilon <= 0.0 || epsilon > 1.0 {
                Err(EngineError::EpsilonOutOfRange { epsilon })
            } else {
                Ok(())
            }
        };
        check_eps(self.epsilon)?;
        if let RankMode::Adaptive { epsilon } = self.rank {
            check_eps(epsilon)?;
        }
        if self.budget == Some(0) {
            return Err(EngineError::ZeroBudget);
        }
        if let Some(explore) = self.explore {
            if !explore.is_finite() || !(0.0..=1.0).contains(&explore) {
                return Err(EngineError::ExploreOutOfRange { explore });
            }
        }

        // -- names -------------------------------------------------------
        let is_graft = is_graft_method(&self.method);
        let probe = if is_graft { None } else { selection::by_name(&self.method, 0) };
        if !is_graft && probe.is_none() {
            return Err(EngineError::UnknownMethod { method: self.method.clone() });
        }
        let grad_pivot = self.pivot == PivotMode::GradAware;
        if grad_pivot && !is_graft {
            return Err(EngineError::PivotNeedsGraft { method: self.method.clone() });
        }
        let extractor: Option<Box<dyn FeatureExtractor>> = match &self.extractor {
            Some(name) => Some(
                features::by_name(name)
                    .ok_or_else(|| EngineError::UnknownExtractor { extractor: name.clone() })?,
            ),
            None => None,
        };
        let merge = match &self.merge {
            MergeSpec::Default => default_merge(&self.method),
            MergeSpec::Policy(p) => *p,
            MergeSpec::Named(s) => MergePolicy::parse(s)
                .ok_or_else(|| EngineError::UnknownMerge { merge: s.clone() })?,
        };

        // -- execution shape (the one cross-knob decision table) ---------
        let requested = match &self.shape {
            ShapeSpec::Knobs { shards, pool_workers, overlap } => {
                ExecShape::from_knobs(*shards, *pool_workers, *overlap)?
            }
            ShapeSpec::Typed(shape) => shape.validate()?,
        };

        // -- shardability fallback (note, not error) ---------------------
        let shardable = is_graft || probe.as_ref().is_some_and(|s| s.shardable());
        let mut notes = Vec::new();
        let shape = match requested {
            ExecShape::Sharded { shards } if !shardable => {
                notes.push(format!(
                    "method '{}' is not shardable (its criterion or cross-batch state would \
                     not survive the MaxVol merge); selection runs serial (shards {shards} \
                     ignored)",
                    self.method
                ));
                ExecShape::Serial
            }
            ExecShape::Pooled { shards, workers, overlap } if shards > 1 && !shardable => {
                notes.push(format!(
                    "method '{}' is not shardable (its criterion or cross-batch state would \
                     not survive the MaxVol merge); the pool hosts it at one shard (shards \
                     {shards} ignored)",
                    self.method
                ));
                ExecShape::Pooled { shards: 1, workers, overlap }
            }
            // A one-shard scoped fan-out is exactly the serial path.
            ExecShape::Sharded { shards: 1 } => ExecShape::Serial,
            s => s,
        };
        let sharded = shape.shards() > 1;
        if grad_pivot && sharded && !merge.gradient_aware() {
            return Err(EngineError::PivotNeedsGradMerge { merge: merge.name().to_string() });
        }
        if self.explore.is_some() && self.method != "hybrid" {
            notes.push(format!(
                "explore fraction only steers the 'hybrid' method; method '{}' ignores it",
                self.method
            ));
        }
        if is_graft && sharded && !merge.gradient_aware() {
            if let RankMode::Adaptive { .. } = self.rank {
                notes.push(format!(
                    "adaptive rank at {} shards needs the gradient-aware merge to apply the \
                     rank decision (merge grad, the GRAFT default); this run's feature-only \
                     merge keeps the full strict budget per refresh",
                    shape.shards()
                ));
            }
        }

        // -- selector construction (trainer wiring, centralised) ---------
        // GRAFT: the run policy sits on the single instance when serial;
        // at shards > 1 the per-shard instances run strict (each emits its
        // full MaxVol pivot prefix, so the merge union is never starved by
        // a local rank cut) and the run policy is hoisted onto the
        // coordinator's ONE rank authority — a single ε/budget accumulator
        // at any shard/worker count.
        let adaptive = matches!(self.rank, RankMode::Adaptive { .. });
        let (mut exec, rebuild) = if is_graft {
            let eps = match self.rank {
                RankMode::Adaptive { epsilon } => epsilon,
                RankMode::Strict => self.epsilon,
            };
            // Hoisted copies: every shape retains `make` as a respawn /
            // rebuild factory (pool workers, sharded workers, or the
            // engine's serial retry), so both closures must be
            // `move + Send + 'static`.
            let (rank, fraction, base_eps) = (self.rank, self.fraction, self.epsilon);
            let run_policy = move || match rank {
                RankMode::Adaptive { epsilon } => BudgetedRankPolicy::adaptive(epsilon, fraction),
                RankMode::Strict => BudgetedRankPolicy::strict(base_eps),
            };
            // On the single-instance shapes (serial, one-shard pool) the
            // gradient-aware pivot re-orders inside the selector itself;
            // at shards > 1 the per-shard instances stay feature-ordered
            // (their full prefix feeds the merge union) and the re-order
            // happens once, at the merge (`MergeCtx::grad_pivot`).
            let make = move |_si: usize| -> Box<dyn Selector> {
                Box::new(
                    GraftSelector::new(if sharded {
                        BudgetedRankPolicy::strict(eps)
                    } else {
                        run_policy()
                    })
                    .with_grad_pivot(grad_pivot && !sharded),
                )
            };
            // Adaptive-only carry: a strict authority's post-merge cut is
            // provably the identity (the feature-only merge already
            // returns min(budget, |union|) rows — pinned bitwise in
            // merge.rs / tests/alloc_free.rs), so installing it would only
            // buy O(shards·r·E) sketch copies per window plus a redundant
            // fused-MGS pass for telemetry the engine can synthesise.
            // Strict sharded/pooled runs carry NO gradient state; their
            // rank accounting comes from the engine's StrictRankTally.
            let authority = (sharded && merge.gradient_aware() && adaptive)
                .then(|| Box::new(GraftSelector::new(run_policy())) as Box<dyn Selector>);
            build_exec(shape, merge, authority, self.sketch_f32, grad_pivot, make)
        } else {
            let (seed, method, explore) = (self.seed, self.method.clone(), self.explore);
            let make = move |si: usize| -> Box<dyn Selector> {
                // Shard 0 keeps the base seed so every shape matches the
                // serial construction of seeded methods.
                let wseed = seed ^ (si as u64).wrapping_mul(0x9E3779B97F4A7C15);
                // `by_name` can only hand out the default explore
                // fraction, so an explicit knob constructs the hybrid
                // directly (same seed derivation either way).
                if method == "hybrid" {
                    if let Some(phi) = explore {
                        return Box::new(selection::hybrid::Hybrid::new(wseed, phi));
                    }
                }
                selection::by_name(&method, wseed).expect("method validated above")
            };
            build_exec(shape, merge, None, self.sketch_f32, false, make)
        };
        // Administrative strict accounting for the shapes that used to get
        // it from the (now-removed) strict rank authority.
        let strict_tally = (is_graft && sharded && merge.gradient_aware() && !adaptive)
            .then(StrictRankTally::default);

        if let Some(d) = self.deadline {
            if let Exec::Pooled(p) = &mut exec {
                p.set_job_deadline(d);
            }
        }

        for n in &notes {
            eprintln!("note: {n}");
        }
        Ok(SelectionEngine::from_parts(
            exec,
            rebuild,
            extractor,
            shape,
            merge,
            self.fraction,
            self.budget,
            self.fault,
            self.seed,
            strict_tally,
            notes,
        ))
    }

    /// Validate the configuration and construct a bounded-memory
    /// [`StreamingEngine`] instead of a batch engine.  Same scalar/name
    /// validation as [`EngineBuilder::build`], plus two streaming rules:
    ///
    /// * an explicit [`EngineBuilder::budget`] is required
    ///   ([`EngineError::StreamNeedsBudget`]) — the reservoir bound is
    ///   `2·budget`, and a fraction of an unknown stream length cannot
    ///   size it (the fraction still steers the adaptive rank policy's
    ///   running average, exactly as in batch mode);
    /// * the method must be a MaxVol criterion that survives incremental
    ///   reservoir maintenance: `graft` / `graft-warm` (gradient-aware
    ///   rank authority + loss top-up) or `maxvol` / `fast-maxvol`
    ///   (feature-only).  Other known methods are
    ///   [`EngineError::StreamUnsupportedMethod`]; unknown names stay
    ///   [`EngineError::UnknownMethod`].
    ///
    /// Streaming always runs serial on the caller's thread (reservoir
    /// maintenance is inherently sequential); a sharded or pooled shape
    /// request is recorded as a note and ignored, mirroring the batch
    /// builder's shardability fallback.
    pub fn build_streaming(self) -> Result<StreamingEngine, EngineError> {
        // -- scalar knobs (same rules as build) --------------------------
        if !self.fraction.is_finite() || self.fraction <= 0.0 || self.fraction > 1.0 {
            return Err(EngineError::FractionOutOfRange { fraction: self.fraction });
        }
        let check_eps = |epsilon: f64| {
            if !epsilon.is_finite() || epsilon <= 0.0 || epsilon > 1.0 {
                Err(EngineError::EpsilonOutOfRange { epsilon })
            } else {
                Ok(())
            }
        };
        check_eps(self.epsilon)?;
        if let RankMode::Adaptive { epsilon } = self.rank {
            check_eps(epsilon)?;
        }
        if self.budget == Some(0) {
            return Err(EngineError::ZeroBudget);
        }
        if let Some(explore) = self.explore {
            if !explore.is_finite() || !(0.0..=1.0).contains(&explore) {
                return Err(EngineError::ExploreOutOfRange { explore });
            }
        }
        let budget = self.budget.ok_or(EngineError::StreamNeedsBudget)?;

        // -- names -------------------------------------------------------
        let is_graft = is_graft_method(&self.method);
        let is_maxvol = matches!(self.method.as_str(), "maxvol" | "fast-maxvol");
        if !is_graft && !is_maxvol {
            return Err(if selection::by_name(&self.method, 0).is_none() {
                EngineError::UnknownMethod { method: self.method }
            } else {
                EngineError::StreamUnsupportedMethod { method: self.method }
            });
        }
        if self.pivot == PivotMode::GradAware && !is_graft {
            return Err(EngineError::PivotNeedsGraft { method: self.method });
        }
        let extractor: Option<Box<dyn FeatureExtractor>> = match &self.extractor {
            Some(name) => Some(
                features::by_name(name)
                    .ok_or_else(|| EngineError::UnknownExtractor { extractor: name.clone() })?,
            ),
            None => None,
        };
        // A named merge spelling still validates (the knob is simply
        // inert on a serial stream).
        if let MergeSpec::Named(s) = &self.merge {
            MergePolicy::parse(s).ok_or_else(|| EngineError::UnknownMerge { merge: s.clone() })?;
        }

        // -- shape: validate, then fall back to serial with a note -------
        let requested = match &self.shape {
            ShapeSpec::Knobs { shards, pool_workers, overlap } => {
                ExecShape::from_knobs(*shards, *pool_workers, *overlap)?
            }
            ShapeSpec::Typed(shape) => shape.validate()?,
        };
        let mut notes = Vec::new();
        if requested != ExecShape::Serial {
            notes.push(
                "streaming sessions run serial on the caller's thread (incremental \
                 reservoir maintenance is sequential); requested execution shape ignored"
                    .to_string(),
            );
        }
        if self.pivot == PivotMode::GradAware {
            notes.push(
                "streaming keeps the feature-volume pivot order (the reservoir is \
                 maintained incrementally; there is no merged union to re-sort); \
                 gradient-aware pivot ignored"
                    .to_string(),
            );
        }
        if self.explore.is_some() {
            notes.push(
                "explore fraction only steers the 'hybrid' method, which cannot stream; \
                 ignored"
                    .to_string(),
            );
        }

        // -- rank authority: one accumulator per engine, as in batch -----
        // Strict GRAFT carries no policy into snapshots at all: a
        // policy-free snapshot already selects depth min(budget, R, len)
        // and tops up by loss — index-identical to what the strict policy
        // would cut (pinned by tests/streaming.rs) — so the reservoir can
        // skip resident sketches entirely and the rank accounting comes
        // from a StrictRankTally, as on the batch shapes.
        let (policy, top_up, strict_tally) = if is_graft {
            match self.rank {
                RankMode::Adaptive { epsilon } => {
                    (Some(BudgetedRankPolicy::adaptive(epsilon, self.fraction)), false, None)
                }
                // Strict GRAFT and feature-only MaxVol both fill the whole
                // budget, topping up past the pivot depth by loss —
                // exactly the batch selectors' contract.
                RankMode::Strict => (None, true, Some(StrictRankTally::default())),
            }
        } else {
            (None, true, None)
        };

        for n in &notes {
            eprintln!("note: {n}");
        }
        Ok(StreamingEngine::from_parts(
            policy,
            top_up,
            budget,
            self.fault,
            self.seed,
            extractor,
            strict_tally,
            self.sketch_f32,
            notes,
        ))
    }
}

/// Wrap per-shard selector instances in the resolved execution shape.
/// `make(0)` uses the base seed, so the serial shape is exactly the
/// unsharded construction.  Every shape keeps the factory reachable for
/// post-panic rebuilds: sharded/pooled executors retain it internally,
/// while the serial shape hands it back for the engine's retry path.
fn build_exec(
    shape: ExecShape,
    merge: MergePolicy,
    authority: Option<Box<dyn Selector>>,
    sketch_f32: bool,
    grad_pivot: bool,
    mut make: impl FnMut(usize) -> Box<dyn Selector> + Send + 'static,
) -> (Exec, Option<Box<dyn FnMut(usize) -> Box<dyn Selector> + Send>>) {
    match shape {
        ExecShape::Serial => {
            let sel = make(0);
            (Exec::Serial(sel), Some(Box::new(make)))
        }
        ExecShape::Sharded { shards } => {
            let mut sel = ShardedSelector::from_factory(shards, merge, make)
                .with_f32_sketches(sketch_f32)
                .with_grad_pivot(grad_pivot);
            if let Some(a) = authority {
                sel = sel.with_rank_authority(a);
            }
            (Exec::Sharded(Box::new(sel)), None)
        }
        ExecShape::Pooled { shards, workers, .. } => {
            let mut sel = PooledSelector::from_factory(shards, workers, merge, make)
                .with_f32_sketches(sketch_f32)
                .with_grad_pivot(grad_pivot);
            if let Some(a) = authority {
                sel = sel.with_rank_authority(a);
            }
            (Exec::Pooled(Box::new(sel)), None)
        }
    }
}

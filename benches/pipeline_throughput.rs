//! Coordinator bench: pipelined batch assembly (producer thread + bounded
//! channel) vs inline assembly — the L3 §Perf optimisation that overlaps
//! host-side gather/one-hot with engine execution.
//!
//! Run: `cargo bench --bench pipeline_throughput`

mod bench_util;

use std::time::Instant;

use bench_util::{black_box, fmt};
use graft::coordinator::BatchProducer;
use graft::data::{loader::Batcher, Dataset};
use graft::rng::Rng;

fn synth(n: usize, d: usize, c: usize) -> Dataset {
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
    Dataset::new("bench", x, y, d, c)
}

/// Pretend-engine latency per step (models the PJRT call).
fn fake_engine_work(micros: u64) {
    let t0 = Instant::now();
    while t0.elapsed().as_micros() < micros as u128 {
        std::hint::spin_loop();
    }
}

fn main() {
    let ds = synth(12_800, 256, 10);
    let bucket = 128;
    let steps = 400;

    for &engine_us in &[0u64, 100, 400] {
        // Inline: assemble then "execute" serially.
        let t0 = Instant::now();
        let mut b = Batcher::new(&ds, bucket, 1);
        for _ in 0..steps {
            let rows: Vec<usize> = b.next_batch().to_vec();
            let x = ds.gather(&rows);
            let y = ds.one_hot(&rows);
            black_box((&x, &y));
            fake_engine_work(engine_us);
        }
        let inline = t0.elapsed().as_secs_f64();

        // Pipelined: producer thread overlaps assembly with execution.
        let t0 = Instant::now();
        let mut p = BatchProducer::spawn(ds.clone(), bucket, steps, 4, 1);
        while let Some(batch) = p.next() {
            black_box((&batch.x, &batch.y1h));
            fake_engine_work(engine_us);
        }
        let piped = t0.elapsed().as_secs_f64();

        println!(
            "engine={engine_us:>4}µs/step   inline {:>10}   pipelined {:>10}   speedup {:.2}x",
            fmt(inline),
            fmt(piped),
            inline / piped
        );
    }
    println!("\n(pipelining pays once engine latency ≥ assembly latency; backpressure bound = 4)");
}

//! Minimal benchmarking harness (criterion is not in the vendored dep
//! closure): warmup + timed repetitions with mean / stddev / min, printed
//! as aligned rows.  Used by every `cargo bench` target.

use std::time::Instant;

/// Time `f` with warmups, returning (mean_s, std_s, min_s) over `reps`.
pub fn time_it<F: FnMut()>(warmups: usize, reps: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmups {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    (mean, var.sqrt(), min)
}

/// Print one benchmark row.
pub fn report(name: &str, mean: f64, std: f64, min: f64) {
    println!("{name:<48} mean {:>12}  ±{:>10}  min {:>12}", fmt(mean), fmt(std), fmt(min));
}

pub fn fmt(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Prevent the optimiser from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

//! Minimal benchmarking harness (criterion is not in the vendored dep
//! closure): warmup + timed repetitions with mean / stddev / min, printed
//! as aligned rows, plus a machine-readable JSON sink so successive PRs
//! can track hot-path regressions (`BENCH_pr1.json` at the repo root; see
//! `scripts/bench.sh`).
#![allow(dead_code)]

use std::path::{Path, PathBuf};
use std::time::Instant;

/// True when `GRAFT_BENCH_SMOKE` is set (and not "0"): benches shrink
/// shapes and repetition counts to CI-smoke sizes.  Smoke runs exist to
/// validate that every bench still executes and emits schema-conformant
/// `graft-bench-v1` rows (see `scripts/validate_bench.py`), not to
/// produce meaningful timings.
pub fn smoke_mode() -> bool {
    std::env::var("GRAFT_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Time `f` with warmups, returning (mean_s, std_s, min_s) over `reps`.
pub fn time_it<F: FnMut()>(warmups: usize, reps: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmups {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    (mean, var.sqrt(), min)
}

/// Print one benchmark row.
pub fn report(name: &str, mean: f64, std: f64, min: f64) {
    println!("{name:<48} mean {:>12}  ±{:>10}  min {:>12}", fmt(mean), fmt(std), fmt(min));
}

pub fn fmt(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Prevent the optimiser from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// JSON sink (schema graft-bench-v1, one record per line)
// ---------------------------------------------------------------------------

/// One timed operation, in nanoseconds.
pub struct BenchRecord {
    /// Bench binary name (records from a re-run replace same-name rows).
    pub bench: String,
    /// Operation label, e.g. "fast_maxvol".
    pub op: String,
    /// Shape string, e.g. "K=2048,R=64".
    pub shape: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchRecord {
    fn to_json(&self) -> String {
        format!(
            "{{\"bench\":\"{}\",\"op\":\"{}\",\"shape\":\"{}\",\"mean_ns\":{:.1},\"std_ns\":{:.1},\"min_ns\":{:.1}}}",
            self.bench, self.op, self.shape, self.mean_ns, self.std_ns, self.min_ns
        )
    }
}

/// Collects records for one bench run and merges them into the shared JSON
/// file on [`JsonSink::write`].
pub struct JsonSink {
    bench: &'static str,
    records: Vec<BenchRecord>,
}

impl JsonSink {
    pub fn new(bench: &'static str) -> JsonSink {
        JsonSink { bench, records: Vec::new() }
    }

    /// Record one timed op; `(mean, std, min)` in seconds as returned by
    /// [`time_it`].
    pub fn record(&mut self, op: &str, shape: &str, timing: (f64, f64, f64)) {
        let (mean, std, min) = timing;
        self.records.push(BenchRecord {
            bench: self.bench.to_string(),
            op: op.to_string(),
            shape: shape.to_string(),
            mean_ns: mean * 1e9,
            std_ns: std * 1e9,
            min_ns: min * 1e9,
        });
    }

    /// Merge into the shared JSON file: existing records from *other*
    /// benches are preserved, rows from this bench are replaced.  Record
    /// extraction locates each `"bench"` key and takes the enclosing
    /// `{…}` object, compacted (whitespace stripped — record fields never
    /// contain spaces), so minified and pretty-printed files both survive
    /// the round-trip.  Concurrent bench runs still race on the
    /// read-modify-write (scripts/bench.sh runs them sequentially).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = default_json_path();
        let mut lines: Vec<String> = Vec::new();
        let own_tag = format!("\"bench\":\"{}\"", self.bench);
        if let Ok(existing) = std::fs::read_to_string(&path) {
            // Records never contain nested braces (all fields are plain
            // bench/op/shape strings + numbers), so an object's extent is
            // the brace pair around each `"bench"` key.
            let mut rest = existing.as_str();
            while let Some(key) = rest.find("\"bench\"") {
                let Some(open) = rest[..key].rfind('{') else {
                    rest = &rest[key + 7..];
                    continue;
                };
                let Some(close) = rest[key..].find('}') else { break };
                let compact: String = rest[open..key + close + 1]
                    .chars()
                    .filter(|c| !c.is_whitespace())
                    .collect();
                if !compact.contains(&own_tag) {
                    lines.push(compact);
                }
                rest = &rest[key + close + 1..];
            }
        }
        lines.extend(self.records.iter().map(BenchRecord::to_json));
        let mut body = String::from("{\"schema\":\"graft-bench-v1\",\"records\":[\n");
        body.push_str(&lines.join(",\n"));
        body.push_str("\n]}\n");
        std::fs::write(&path, body)?;
        Ok(path)
    }
}

/// Output path for the shared bench JSON: `$GRAFT_BENCH_JSON` if set, else
/// `BENCH_pr1.json` at the repo root (one level above the crate manifest).
pub fn default_json_path() -> PathBuf {
    match std::env::var("GRAFT_BENCH_JSON") {
        Ok(p) => PathBuf::from(p),
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr1.json"),
    }
}

//! Serve-path bench: one tenant's `SubmitBatch` + `GetSelection`
//! roundtrip over a loopback TCP daemon vs the same selection run
//! in-process, so later PRs can track the wire/codec overhead.  The
//! bench refuses to time a transport that lies: before the clock starts
//! it pins served ≡ in-process bit-identity on fresh windows.
//!
//! Rows land in the shared bench JSON (schema `graft-bench-v1`), op
//! family `serve_roundtrip` / `serve_inproc_select`.
//!
//! Run: `cargo bench --bench serve_loopback` (or `scripts/bench.sh`).
//! `GRAFT_BENCH_SMOKE=1` shrinks shapes/reps to CI-smoke sizes.

mod bench_util;

use bench_util::{black_box, report, smoke_mode, time_it, JsonSink};
use graft::coordinator::SelectWindow;
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::serve::protocol::TenantConfig;
use graft::serve::{engine_builder, Client, ServerBuilder};

fn window(k: usize, seed: u64) -> SelectWindow {
    let (rc, e, classes) = (16usize, 16usize, 10usize);
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    SelectWindow {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

fn main() {
    let mut sink = JsonSink::new("serve_loopback");
    let (k, budget, warm, reps) =
        if smoke_mode() { (256usize, 16usize, 1usize, 3usize) } else { (4096, 64, 2, 10) };
    let shape = format!("K={k},R=16,budget={budget}");
    println!("== serve loopback roundtrip (K={k}, budget={budget}) ==\n");

    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let cfg = TenantConfig { budget: budget as u64, seed: 9, ..TenantConfig::default() };

    let mut client = Client::connect_tcp(&addr).expect("connect");
    client.hello("bench", &cfg).expect("hello");
    let mut inproc = engine_builder(&cfg).build().expect("in-process engine");

    // Bit-identity preflight on fresh windows: a transport that changes
    // the answer has no business being timed.
    for w in 0..3u64 {
        let win = window(k, 0xB0B + w);
        let served = client.select(&win.view()).expect("served select").indices;
        let want: Vec<u64> = inproc
            .select(&win.view())
            .expect("in-process select")
            .indices
            .iter()
            .map(|&i| i as u64)
            .collect();
        assert_eq!(served, want, "served selection diverged from in-process at window {w}");
    }

    let win = window(k, 0xFEED);
    let view = win.view();

    let wire = time_it(warm, reps, || {
        black_box(client.select(&view).expect("served select").indices.len());
    });
    report("serve_roundtrip", wire.0, wire.1, wire.2);
    sink.record("serve_roundtrip", &shape, wire);

    let local = time_it(warm, reps, || {
        black_box(inproc.select(&view).expect("in-process select").indices.len());
    });
    report("serve_inproc_select", local.0, local.1, local.2);
    sink.record("serve_inproc_select", &shape, local);

    client.bye().expect("bye");
    server.shutdown();

    match sink.write() {
        Ok(path) => println!("\nbench JSON → {}", path.display()),
        Err(e) => eprintln!("\nWARN could not write bench JSON: {e}"),
    }
}

//! Runtime hot-path bench: per-artifact PJRT execution latency for every
//! artifact kind (embed / select / train buckets / eval) on the cifar10
//! config — the numbers behind the §Perf L3 accounting and the end-to-end
//! step-time budget of Tables 8-14.
//!
//! Requires `make artifacts`.  Run: `cargo bench --bench runtime_hotpath`

mod bench_util;

use bench_util::{report, time_it};
use graft::rng::Rng;
use graft::runtime::{default_dir, Engine, TrainState};

fn main() -> anyhow::Result<()> {
    let mut engine = match Engine::new(default_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP runtime bench: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    let config = "cifar10";
    let spec = engine.spec(config)?.clone();
    engine.warmup(config)?;

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..spec.k * spec.d).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; spec.k * spec.c];
    for i in 0..spec.k {
        y[i * spec.c + rng.below(spec.c)] = 1.0;
    }
    let mut state = TrainState::init(&spec, 42);

    println!("== runtime hot path (config {config}: K={}, D={}, Rmax={}) ==\n", spec.k, spec.d, spec.rmax);

    let params = state.params.clone();
    let (m, s, mn) = time_it(3, 20, || {
        engine.embed(config, &params, &x, &y).unwrap();
    });
    report("embed (features+sketches)", m, s, mn);

    let (m, s, mn) = time_it(3, 20, || {
        engine.select(config, &params, &x, &y).unwrap();
    });
    report("select (L1 Pallas maxvol+proj)", m, s, mn);

    let (m, s, mn) = time_it(3, 20, || {
        engine.eval_step(config, &params, &x, &y).unwrap();
    });
    report("eval_step", m, s, mn);

    for &bucket in &spec.buckets.clone() {
        let xb = x[..bucket * spec.d].to_vec();
        let yb = y[..bucket * spec.c].to_vec();
        let w = vec![1.0 / bucket as f32; bucket];
        let (m, s, mn) = time_it(3, 20, || {
            engine
                .train_step(config, bucket, &mut state, &xb, &yb, &w, 0.01, 0.9)
                .unwrap();
        });
        report(&format!("train_step bucket={bucket}"), m, s, mn);
    }

    let st = engine.stats();
    println!(
        "\nengine: {} compiles ({:.2}s), {} executions ({:.2}s total)",
        st.compiles, st.compile_secs, st.executions, st.exec_secs
    );
    Ok(())
}

//! Runtime hot-path bench: per-artifact PJRT execution latency for every
//! artifact kind (embed / select / train buckets / eval) on the cifar10
//! config — the numbers behind the §Perf L3 accounting and the end-to-end
//! step-time budget of Tables 8-14.  Rows land in `BENCH_pr1.json` next to
//! the table4 kernel rows.
//!
//! Requires `make artifacts`.  Run: `cargo bench --bench runtime_hotpath`

mod bench_util;

use bench_util::{report, smoke_mode, time_it, JsonSink};
use graft::rng::Rng;
use graft::runtime::{default_dir, Engine, TrainState};

fn main() -> anyhow::Result<()> {
    let mut sink = JsonSink::new("runtime_hotpath");
    let (warm, reps) = if smoke_mode() { (1, 2) } else { (3, 20) };
    let mut engine = match Engine::new(default_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP runtime bench: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    let config = "cifar10";
    let spec = engine.spec(config)?.clone();
    engine.warmup(config)?;

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..spec.k * spec.d).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; spec.k * spec.c];
    for i in 0..spec.k {
        y[i * spec.c + rng.below(spec.c)] = 1.0;
    }
    let mut state = TrainState::init(&spec, 42);

    println!("== runtime hot path (config {config}: K={}, D={}, Rmax={}) ==\n", spec.k, spec.d, spec.rmax);
    let shape = format!("K={},D={},Rmax={}", spec.k, spec.d, spec.rmax);

    let params = state.params.clone();
    let t = time_it(warm, reps, || {
        engine.embed(config, &params, &x, &y).unwrap();
    });
    report("embed (features+sketches)", t.0, t.1, t.2);
    sink.record("embed", &shape, t);

    let t = time_it(warm, reps, || {
        engine.select(config, &params, &x, &y).unwrap();
    });
    report("select (L1 Pallas maxvol+proj)", t.0, t.1, t.2);
    sink.record("select", &shape, t);

    let t = time_it(warm, reps, || {
        engine.eval_step(config, &params, &x, &y).unwrap();
    });
    report("eval_step", t.0, t.1, t.2);
    sink.record("eval_step", &shape, t);

    for &bucket in &spec.buckets.clone() {
        let xb = x[..bucket * spec.d].to_vec();
        let yb = y[..bucket * spec.c].to_vec();
        let w = vec![1.0 / bucket as f32; bucket];
        let t = time_it(warm, reps, || {
            engine
                .train_step(config, bucket, &mut state, &xb, &yb, &w, 0.01, 0.9)
                .unwrap();
        });
        report(&format!("train_step bucket={bucket}"), t.0, t.1, t.2);
        sink.record("train_step", &format!("bucket={bucket}"), t);
    }

    let st = engine.stats();
    println!(
        "\nengine: {} compiles ({:.2}s), {} executions ({:.2}s total)",
        st.compiles, st.compile_secs, st.executions, st.exec_secs
    );
    match sink.write() {
        Ok(path) => println!("bench JSON → {}", path.display()),
        Err(e) => eprintln!("WARN could not write bench JSON: {e}"),
    }
    Ok(())
}

//! Table 4 bench: Fast MaxVol vs CrossMaxVol selection latency on Iris
//! (the paper reports 0.000539 s vs 0.045594 s — an 84.6× speedup) plus
//! the subspace-similarity metric, and the PR 1 hot-path regression rows
//! (workspace fast_maxvol vs the pre-PR reference, blocked vs naive
//! matmul/gram) written to `BENCH_pr1.json`.
//!
//! Run: `cargo bench --bench table4_maxvol` (or `scripts/bench.sh`)

mod bench_util;

use bench_util::{black_box, report, smoke_mode, time_it, JsonSink};
use graft::data::iris::iris;
use graft::features::{FeatureExtractor, SvdFeatures};
use graft::linalg::{subspace_similarity_normalised, svd, Mat, Workspace};
use graft::selection::cross_maxvol::CrossMaxVol;
use graft::selection::maxvol::{
    conventional_maxvol, conventional_maxvol_reference, fast_maxvol, fast_maxvol_reference,
    fast_maxvol_with,
};

fn main() {
    let mut sink = JsonSink::new("table4_maxvol");
    let smoke = smoke_mode();
    let ds = iris();
    let r = 3; // r = d would be degenerate: any independent 4 rows span R^4
    let x = Mat::from_fn(ds.n, ds.d, |i, j| ds.row(i)[j] as f64);
    let feats = SvdFeatures.extract(&x, r);

    println!("== Table 4: Fast MaxVol vs CrossMaxVol (Iris, R = {r}) ==\n");
    let t_fast = time_it(10, if smoke { 20 } else { 200 }, || {
        black_box(fast_maxvol(&feats, r));
    });
    report("fast_maxvol (ours)", t_fast.0, t_fast.1, t_fast.2);
    sink.record("fast_maxvol", "iris:K=150,R=3", t_fast);

    let cm = CrossMaxVol::default();
    let t_cross = time_it(5, if smoke { 10 } else { 100 }, || {
        black_box(cm.select_rows(&x, r));
    });
    report("cross_maxvol (Cross-2D baseline)", t_cross.0, t_cross.1, t_cross.2);
    sink.record("cross_maxvol", "iris:K=150,R=3", t_cross);

    let t_conv = time_it(5, if smoke { 10 } else { 50 }, || {
        black_box(conventional_maxvol(&feats, r, 1.01, 100));
    });
    report("conventional_maxvol (Sherman-Morrison)", t_conv.0, t_conv.1, t_conv.2);
    sink.record("conventional_maxvol", "iris:K=150,R=3", t_conv);

    println!("\nspeedup fast vs cross: {:.1}x  (paper: 84.6x)", t_cross.0 / t_fast.0);

    // Similarity metric (paper: 0.6250 vs 0.5938).
    let p_fast = fast_maxvol(&feats, r);
    let (p_cross, _) = cm.select_rows(&x, r);
    let opt = {
        let d = svd(&x);
        let idx: Vec<usize> = (0..r).collect();
        d.v.take_cols(&idx)
    };
    let sim = |rows: &[usize]| subspace_similarity_normalised(&x.take_rows(rows).transpose(), &opt);
    println!(
        "similarity: fast {:.4} vs cross {:.4}  (paper: 0.6250 vs 0.5938)",
        sim(&p_fast),
        sim(&p_cross)
    );

    // ---- batch-scale selection: the PR 1 headline -----------------------
    let (bk, br, breps) = if smoke { (256usize, 32usize, 3usize) } else { (2048, 64, 20) };
    let big_shape = format!("K={bk},R={br}");
    println!("\n-- batch-scale selection (K = {bk}, R = {br}) --");
    let mut rng = graft::rng::Rng::new(9);
    let big = Mat::from_fn(bk, br, |_, _| rng.normal());
    let mut ws = Workspace::new();
    let mut out: Vec<usize> = Vec::with_capacity(br);
    let t_ws = time_it(3, breps, || {
        fast_maxvol_with(&big, br, &mut ws, &mut out);
        black_box(out.len());
    });
    report(&format!("fast_maxvol K={bk} R={br} (workspace)"), t_ws.0, t_ws.1, t_ws.2);
    sink.record("fast_maxvol", &big_shape, t_ws);

    let t_ref = time_it(3, breps, || {
        black_box(fast_maxvol_reference(&big, br));
    });
    report(&format!("fast_maxvol K={bk} R={br} (pre-PR ref)"), t_ref.0, t_ref.1, t_ref.2);
    sink.record("fast_maxvol_reference", &big_shape, t_ref);
    println!("speedup vs pre-PR reference: {:.2}x", t_ref.0 / t_ws.0);

    // Conventional MaxVol at batch scale: Sherman-Morrison vs re-inversion.
    let cr = br / 2;
    let conv_shape = format!("K={bk},r={cr}");
    let t_sm = time_it(2, breps.min(10), || {
        black_box(conventional_maxvol(&big, cr, 1.01, 100));
    });
    report(&format!("conventional_maxvol K={bk} r={cr} (SM)"), t_sm.0, t_sm.1, t_sm.2);
    sink.record("conventional_maxvol", &conv_shape, t_sm);
    let t_re = time_it(2, breps.min(10), || {
        black_box(conventional_maxvol_reference(&big, cr, 1.01, 100));
    });
    report(&format!("conventional_maxvol K={bk} r={cr} (ref)"), t_re.0, t_re.1, t_re.2);
    sink.record("conventional_maxvol_reference", &conv_shape, t_re);

    // ---- blocked linalg kernels vs scalar references --------------------
    let (mm, mk, mn) = if smoke { (128usize, 64usize, 128usize) } else { (512, 256, 512) };
    let mm_shape = format!("{mm}x{mk}x{mn}");
    println!("\n-- blocked kernels ({mm}x{mk} · {mk}x{mn}) --");
    let a = Mat::from_fn(mm, mk, |_, _| rng.normal());
    let b = Mat::from_fn(mk, mn, |_, _| rng.normal());
    let t_mm = time_it(2, breps.min(10), || {
        black_box(a.matmul(&b).rows());
    });
    report("matmul (blocked+threaded)", t_mm.0, t_mm.1, t_mm.2);
    sink.record("matmul", &mm_shape, t_mm);
    let t_mn = time_it(2, breps.min(10), || {
        black_box(a.matmul_naive(&b).rows());
    });
    report("matmul (pre-PR naive)", t_mn.0, t_mn.1, t_mn.2);
    sink.record("matmul_naive", &mm_shape, t_mn);

    let (gk, gr) = if smoke { (256usize, 64usize) } else { (2048, 128) };
    let g_shape = format!("{gk}x{gr}");
    let g = Mat::from_fn(gk, gr, |_, _| rng.normal());
    let t_gb = time_it(2, breps.min(10), || {
        black_box(g.gram().rows());
    });
    report(&format!("gram {gk}x{gr} (blocked+threaded)"), t_gb.0, t_gb.1, t_gb.2);
    sink.record("gram", &g_shape, t_gb);
    let t_gn = time_it(2, breps.min(10), || {
        black_box(g.gram_naive().rows());
    });
    report(&format!("gram {gk}x{gr} (pre-PR naive)"), t_gn.0, t_gn.1, t_gn.2);
    sink.record("gram_naive", &g_shape, t_gn);

    match sink.write() {
        Ok(path) => println!("\nbench JSON → {}", path.display()),
        Err(e) => eprintln!("\nWARN could not write bench JSON: {e}"),
    }
}

//! Table 4 bench: Fast MaxVol vs CrossMaxVol selection latency on Iris
//! (the paper reports 0.000539 s vs 0.045594 s — an 84.6× speedup) plus
//! the subspace-similarity metric.
//!
//! Run: `cargo bench --bench table4_maxvol`

mod bench_util;

use bench_util::{black_box, report, time_it};
use graft::data::iris::iris;
use graft::features::{FeatureExtractor, SvdFeatures};
use graft::linalg::{subspace_similarity_normalised, svd, Mat};
use graft::selection::cross_maxvol::CrossMaxVol;
use graft::selection::maxvol::{conventional_maxvol, fast_maxvol};

fn main() {
    let ds = iris();
    let r = 3; // r = d would be degenerate: any independent 4 rows span R^4
    let x = Mat::from_fn(ds.n, ds.d, |i, j| ds.row(i)[j] as f64);
    let feats = SvdFeatures.extract(&x, r);

    println!("== Table 4: Fast MaxVol vs CrossMaxVol (Iris, R = {r}) ==\n");
    let (mean_f, std_f, min_f) = time_it(10, 200, || {
        black_box(fast_maxvol(&feats, r));
    });
    report("fast_maxvol (ours)", mean_f, std_f, min_f);

    let cm = CrossMaxVol::default();
    let (mean_c, std_c, min_c) = time_it(5, 100, || {
        black_box(cm.select_rows(&x, r));
    });
    report("cross_maxvol (Cross-2D baseline)", mean_c, std_c, min_c);

    let (mean_v, std_v, min_v) = time_it(5, 50, || {
        black_box(conventional_maxvol(&feats, r, 1.01, 100));
    });
    report("conventional_maxvol (Goreinov swap)", mean_v, std_v, min_v);

    println!("\nspeedup fast vs cross: {:.1}x  (paper: 84.6x)", mean_c / mean_f);

    // Similarity metric (paper: 0.6250 vs 0.5938).
    let p_fast = fast_maxvol(&feats, r);
    let (p_cross, _) = cm.select_rows(&x, r);
    let opt = {
        let d = svd(&x);
        let idx: Vec<usize> = (0..r).collect();
        d.v.take_cols(&idx)
    };
    let sim = |rows: &[usize]| subspace_similarity_normalised(&x.take_rows(rows).transpose(), &opt);
    println!(
        "similarity: fast {:.4} vs cross {:.4}  (paper: 0.6250 vs 0.5938)",
        sim(&p_fast),
        sim(&p_cross)
    );

    // Larger-scale sanity: K = 2048, R = 64 (one CIFAR-like batch).
    println!("\n-- batch-scale selection (K = 2048, R = 64) --");
    let mut rng = graft::rng::Rng::new(9);
    let big = Mat::from_fn(2048, 64, |_, _| rng.normal());
    let (mean_b, std_b, min_b) = time_it(2, 10, || {
        black_box(fast_maxvol(&big, 64));
    });
    report("fast_maxvol K=2048 R=64", mean_b, std_b, min_b);
}

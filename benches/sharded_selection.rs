//! Sharded selection bench: single-shot Fast MaxVol selection vs the
//! `ShardedSelector` fan-out + hierarchical MaxVol merge at shards ∈
//! {2, 4, 8}, the flat-merge reference shape, and (PR 3) the persistent
//! `PooledSelector` worker pool against the per-refresh scoped threads it
//! replaces (`select_pooled` vs `select_sharded` rows, matched and
//! oversubscribed worker counts).  Rows land in `BENCH_pr1.json` (schema
//! `graft-bench-v1`) next to the PR 1 kernel rows so later scaling PRs can
//! track the fan-out overhead/crossover.
//!
//! Run: `cargo bench --bench sharded_selection` (or `scripts/bench.sh`).
//! `GRAFT_BENCH_SMOKE=1` shrinks shapes/reps to CI-smoke sizes.

mod bench_util;

use std::time::Duration;

use bench_util::{report, smoke_mode, time_it, JsonSink};
use graft::coordinator::{MergePolicy, PooledSelector, ShardedSelector};
use graft::engine::{EngineBuilder, ExecShape, FaultPolicy, PivotMode};
use graft::faults::FaultPlan;
use graft::graft::{BudgetedRankPolicy, GraftSelector};
use graft::linalg::{Mat, Workspace};
use graft::rng::Rng;
use graft::selection::maxvol::FastMaxVol;
use graft::selection::{BatchView, Selector};

fn main() {
    let mut sink = JsonSink::new("sharded_selection");
    let (k, rc, e, r, warm, reps) =
        if smoke_mode() { (256, 16, 16, 32, 1, 3) } else { (8192, 64, 64, 512, 2, 10) };

    let mut rng = Rng::new(11);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % 10) as i32).collect();
    let preds = labels.clone();
    let row_ids: Vec<usize> = (0..k).collect();
    let view = BatchView {
        features: &features,
        grads: &grads,
        losses: &losses,
        labels: &labels,
        preds: &preds,
        classes: 10,
        row_ids: &row_ids,
    };
    let shape = format!("K={k},R={rc},r={r}");
    println!("== sharded selection (K={k}, R={rc}, r={r}) ==\n");

    let mut ws = Workspace::new();
    let mut out: Vec<usize> = Vec::new();

    let mut single = FastMaxVol;
    let t = time_it(warm, reps, || {
        single.select_into(&view, r, &mut ws, &mut out);
    });
    report("single-shot select (shards=1)", t.0, t.1, t.2);
    sink.record("select_single", &shape, t);
    let baseline = out.clone();

    for shards in [2usize, 4, 8] {
        let mut sel = ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, |_| {
            Box::new(FastMaxVol)
        });
        let t = time_it(warm, reps, || {
            sel.select_into(&view, r, &mut ws, &mut out);
        });
        report(&format!("sharded select (shards={shards}, hierarchical)"), t.0, t.1, t.2);
        sink.record("select_sharded", &format!("{shape},shards={shards}"), t);
        assert_eq!(out.len(), baseline.len(), "sharded selection broke the budget contract");
    }

    // Persistent pool vs per-refresh scoped threads (PR 3): same shard
    // counts, workers ∈ {matched, oversubscribed}.  Bit-identity with the
    // scoped rows is asserted inline, so a silent divergence fails the
    // bench (and the CI smoke run) rather than polluting the JSON.
    for (shards, workers) in [(2usize, 2usize), (4, 4), (8, 8), (8, 2)] {
        let mut sel = PooledSelector::from_factory(shards, workers, MergePolicy::Hierarchical, |_| {
            Box::new(FastMaxVol)
        });
        let t = time_it(warm, reps, || {
            sel.select_into(&view, r, &mut ws, &mut out);
        });
        report(&format!("pooled select (shards={shards}, workers={workers})"), t.0, t.1, t.2);
        sink.record("select_pooled", &format!("{shape},shards={shards},workers={workers}"), t);
        let mut scoped_ref = ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, |_| {
            Box::new(FastMaxVol)
        });
        let mut scoped_out: Vec<usize> = Vec::new();
        scoped_ref.select_into(&view, r, &mut ws, &mut scoped_out);
        assert_eq!(out, scoped_out, "pool≡scoped bit-identity broke at shards={shards} workers={workers}");
    }

    // Gradient-aware merge (PR 4): GRAFT shard instances + one top-level
    // rank authority — the fully-GRAFT sharded path, priced against the
    // feature-only rows above.  A strict authority's rank decision is the
    // identity, so the subset must equal the feature-only merge bit for
    // bit; a silent divergence fails the bench (and the CI smoke run).
    for shards in [2usize, 4, 8] {
        let mut sel = ShardedSelector::from_factory(shards, MergePolicy::Grad, |_| {
            Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
        })
        .with_rank_authority(Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05))));
        let t = time_it(warm, reps, || {
            sel.select_into(&view, r, &mut ws, &mut out);
        });
        report(&format!("grad-merge select (shards={shards}, graft)"), t.0, t.1, t.2);
        sink.record("select_sharded_gradmerge", &format!("{shape},shards={shards}"), t);
        let mut feature_only =
            ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, |_| {
                Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
            });
        let mut fout: Vec<usize> = Vec::new();
        feature_only.select_into(&view, r, &mut ws, &mut fout);
        assert_eq!(out, fout, "strict grad-merge ≡ feature-only broke at shards={shards}");
    }

    // Flat merge at the widest fan-out: the single big second-stage MaxVol
    // the tournament tree avoids.
    let mut flat =
        ShardedSelector::from_factory(8, MergePolicy::Flat, |_| Box::new(FastMaxVol));
    let t = time_it(warm, reps, || {
        flat.select_into(&view, r, &mut ws, &mut out);
    });
    report("sharded select (shards=8, flat merge)", t.0, t.1, t.2);
    sink.record("select_sharded_flat", &format!("{shape},shards=8"), t);

    // SelectionEngine facade rows (PR 5): the same shapes driven through
    // the typed API, priced against the direct-construction rows above.
    // Bit-identity engine ≡ direct is asserted inline per shape, so a
    // facade that silently drifts from the coordinator path fails the
    // bench (and the CI smoke run) rather than polluting the JSON.
    for shards in [2usize, 4] {
        let mut eng = EngineBuilder::new()
            .method("maxvol")
            .budget(r)
            .exec(ExecShape::Sharded { shards })
            .build()
            .expect("valid engine config");
        let t = time_it(warm, reps, || {
            let sel = eng.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report(&format!("engine select (shards={shards}, facade)"), t.0, t.1, t.2);
        sink.record("select_engine_sharded", &format!("{shape},shards={shards}"), t);
        let mut direct = ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, |_| {
            Box::new(FastMaxVol)
        });
        direct.select_into(&view, r, &mut ws, &mut out);
        assert_eq!(
            eng.select(&view).expect("healthy selection").indices,
            &out[..],
            "engine≡direct bit-identity broke at shards={shards}"
        );
    }

    {
        let (shards, workers) = (4usize, 2usize);
        let mut eng = EngineBuilder::new()
            .method("maxvol")
            .budget(r)
            .exec(ExecShape::Pooled { shards, workers, overlap: false })
            .build()
            .expect("valid engine config");
        let t = time_it(warm, reps, || {
            let sel = eng.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report(&format!("engine select (pooled {shards}x{workers}, facade)"), t.0, t.1, t.2);
        sink.record(
            "select_engine_pooled",
            &format!("{shape},shards={shards},workers={workers}"),
            t,
        );
        let mut direct = PooledSelector::from_factory(shards, workers, MergePolicy::Hierarchical, |_| {
            Box::new(FastMaxVol)
        });
        direct.select_into(&view, r, &mut ws, &mut out);
        assert_eq!(
            eng.select(&view).expect("healthy selection").indices,
            &out[..],
            "engine≡direct pooled bit-identity broke"
        );
    }

    {
        // Gradient-aware facade row: engine-built GRAFT shards + rank
        // authority vs the hand-wired construction.
        let shards = 4usize;
        let mut eng = EngineBuilder::new()
            .method("graft")
            .budget(r)
            .epsilon(0.05)
            .exec(ExecShape::Sharded { shards })
            .build()
            .expect("valid engine config");
        let t = time_it(warm, reps, || {
            let sel = eng.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report(&format!("engine select (shards={shards}, grad merge, facade)"), t.0, t.1, t.2);
        sink.record("select_engine_gradmerge", &format!("{shape},shards={shards}"), t);
        let mut direct = ShardedSelector::from_factory(shards, MergePolicy::Grad, |_| {
            Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
        })
        .with_rank_authority(Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05))));
        direct.select_into(&view, r, &mut ws, &mut out);
        assert_eq!(
            eng.select(&view).expect("healthy selection").indices,
            &out[..],
            "engine≡direct grad-merge bit-identity broke"
        );
    }

    // Adaptive-only carry rows (PR 9): strict grad-merge engines install
    // no rank authority, so zero gradient-sketch bytes cross the
    // shard→merge boundary and the post-merge fused-MGS telemetry pass
    // disappears.  Priced against the legacy carry wiring (the
    // select_sharded_gradmerge rows above) with the bit-identity and the
    // zero-carry claim asserted inline.
    for shards in [2usize, 4, 8] {
        let mut eng = EngineBuilder::new()
            .method("graft")
            .budget(r)
            .epsilon(0.05)
            .exec(ExecShape::Sharded { shards })
            .build()
            .expect("valid engine config");
        let t = time_it(warm, reps, || {
            let sel = eng.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report(&format!("strict no-carry select (shards={shards}, graft)"), t.0, t.1, t.2);
        sink.record("select_strict_nocarry", &format!("{shape},shards={shards}"), t);
        assert_eq!(
            eng.carried_sketch_bytes(),
            0,
            "strict engine carried sketches at shards={shards}"
        );
        let mut legacy = ShardedSelector::from_factory(shards, MergePolicy::Grad, |_| {
            Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
        })
        .with_rank_authority(Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05))));
        legacy.select_into(&view, r, &mut ws, &mut out);
        assert_eq!(
            eng.select(&view).expect("healthy selection").indices,
            &out[..],
            "no-carry≡legacy-carry bit-identity broke at shards={shards}"
        );
    }

    // Gradient-aware pivot rows (PR 10): GRAFT with `PivotMode::GradAware`
    // on the serial and sharded shapes, pricing the fused-MGS re-ordering
    // pass against the feature-order engines above.  With budget ≥ feature
    // width the strict cut keeps the whole pivot prefix, so the ordering
    // change cannot move membership — asserted inline as sorted-set
    // identity against the no-pivot engine, which keeps the family honest
    // without over-pinning the order itself.
    for shards in [1usize, 4] {
        let exec = if shards == 1 { ExecShape::Serial } else { ExecShape::Sharded { shards } };
        let mut eng = EngineBuilder::new()
            .method("graft")
            .budget(r)
            .epsilon(0.05)
            .exec(exec)
            .pivot(PivotMode::GradAware)
            .build()
            .expect("valid engine config");
        let t = time_it(warm, reps, || {
            let sel = eng.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report(&format!("grad-pivot select (shards={shards}, graft)"), t.0, t.1, t.2);
        sink.record("select_gradpivot", &format!("{shape},shards={shards}"), t);
        let mut plain = EngineBuilder::new()
            .method("graft")
            .budget(r)
            .epsilon(0.05)
            .exec(exec)
            .build()
            .expect("valid engine config");
        let mut got = eng.select(&view).expect("healthy selection").indices.to_vec();
        let mut want = plain.select(&view).expect("healthy selection").indices.to_vec();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "grad pivot moved membership at shards={shards} (budget ≥ width)");
    }

    // Fault-path rows (fault-tolerance PR): the pooled facade priced under
    // each fault policy.  Two zero-fault rows pin that the retry machinery
    // costs nothing when healthy — and, asserted inline, that a zero-fault
    // `Retry` run is bit-identical to `Fail`.  A third row prices a
    // retried epoch: every measured select eats one injected shard panic,
    // paying a worker respawn + job resubmission on top of the normal
    // work, and must still land the fault-free subset.
    {
        let (shards, workers) = (4usize, 2usize);
        let pshape = format!("{shape},shards={shards},workers={workers}");
        let build = |policy: FaultPolicy| {
            EngineBuilder::new()
                .method("maxvol")
                .budget(r)
                .exec(ExecShape::Pooled { shards, workers, overlap: false })
                .fault_policy(policy)
                .build()
                .expect("valid engine config")
        };
        let mut fail = build(FaultPolicy::Fail);
        let mut retry = build(FaultPolicy::Retry { max: 2, backoff: Duration::ZERO });
        let base = fail.select(&view).expect("healthy selection").indices.to_vec();
        assert_eq!(
            retry.select(&view).expect("healthy selection").indices,
            &base[..],
            "zero-fault Retry must be bit-identical to Fail"
        );
        let t = time_it(warm, reps, || {
            let sel = fail.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report("faultpath select (pooled 4x2, Fail, zero faults)", t.0, t.1, t.2);
        sink.record("select_faultpath", &format!("{pshape},policy=fail"), t);
        let t = time_it(warm, reps, || {
            let sel = retry.select(&view).expect("healthy selection");
            bench_util::black_box(sel.indices.len());
        });
        report("faultpath select (pooled 4x2, Retry, zero faults)", t.0, t.1, t.2);
        sink.record("select_faultpath", &format!("{pshape},policy=retry"), t);

        // One injected panic per measured epoch: shard 0's first run of
        // each window fails, the retry (same window, event spent) heals.
        let mut injected = build(FaultPolicy::Retry { max: 2, backoff: Duration::ZERO });
        let runs = (warm + reps) as u64;
        let plan = (1..=runs).fold(FaultPlan::new(), |p, w| p.panic_shard(0, w));
        injected.set_fault_injector(Some(plan.arc()));
        let t = time_it(warm, reps, || {
            let sel = injected.select(&view).expect("retry heals the injected panic");
            bench_util::black_box(sel.indices.len());
        });
        report("faultpath select (pooled 4x2, Retry, 1 panic/epoch)", t.0, t.1, t.2);
        sink.record("select_faultpath", &format!("{pshape},policy=retry,faults=1"), t);
        assert!(
            injected.fault_stats().retries >= runs,
            "every epoch should have retried once"
        );
        assert_eq!(
            injected.select(&view).expect("healthy selection").indices,
            &base[..],
            "retried epochs must converge to the fault-free subset"
        );
    }

    // Streaming engine rows (PR 7): the bounded-memory reservoir path
    // priced at two chunk sizes.  K is 8× the reservoir capacity (2r), so
    // these rows price genuine steady-state elimination/admission churn,
    // not the growth phase.  Two inline asserts keep the family honest:
    // chunked arrival must be bit-identical to a single whole-view push
    // (chunking invariance), and on a reservoir-sized window the stream
    // must reproduce the batch FastMaxVol subset bit for bit.
    {
        let mut se = EngineBuilder::new()
            .method("maxvol")
            .budget(r)
            .build_streaming()
            .expect("valid streaming config");
        let cap = se.reservoir_capacity();
        for chunk in [cap / 4, k] {
            let t = time_it(warm, reps, || {
                se.reset();
                let mut lo = 0usize;
                while lo < k {
                    let hi = (lo + chunk).min(k);
                    se.push_range(&view, lo..hi).expect("clean stream push");
                    lo = hi;
                }
                let snap = se.snapshot().expect("clean stream snapshot");
                bench_util::black_box(snap.indices.len());
            });
            report(&format!("streaming select (reservoir={cap}, chunk={chunk})"), t.0, t.1, t.2);
            sink.record("select_streaming", &format!("{shape},chunk={chunk}"), t);
        }

        // Chunking invariance: one whole-view push vs ragged chunks.
        se.reset();
        se.push(&view).expect("clean stream push");
        let whole = se.snapshot().expect("clean stream snapshot").indices;
        se.reset();
        let mut lo = 0usize;
        while lo < k {
            let hi = (lo + 97).min(k);
            se.push_range(&view, lo..hi).expect("clean stream push");
            lo = hi;
        }
        let chunked = se.snapshot().expect("clean stream snapshot").indices;
        assert_eq!(chunked, whole, "chunked arrival changed the streamed selection");

        // Stream ≡ batch where the reservoir holds the whole window.
        let kw = cap.min(k);
        let mut wrng = Rng::new(23);
        let wfeat = Mat::from_fn(kw, rc, |_, _| wrng.normal());
        let wgrads = Mat::from_fn(kw, e, |_, _| wrng.normal());
        let wlosses: Vec<f64> = (0..kw).map(|_| wrng.uniform() * 2.0).collect();
        let wlabels: Vec<i32> = (0..kw).map(|i| (i % 10) as i32).collect();
        let wids: Vec<usize> = (0..kw).collect();
        let wview = BatchView {
            features: &wfeat,
            grads: &wgrads,
            losses: &wlosses,
            labels: &wlabels,
            preds: &wlabels,
            classes: 10,
            row_ids: &wids,
        };
        se.reset();
        se.push(&wview).expect("clean stream push");
        let streamed = se.snapshot().expect("clean stream snapshot").indices;
        single.select_into(&wview, r.min(kw), &mut ws, &mut out);
        assert_eq!(streamed, out, "stream≡batch bit-identity broke on a reservoir-sized window");
    }

    match sink.write() {
        Ok(path) => println!("\nbench JSON → {}", path.display()),
        Err(e) => eprintln!("\nWARN could not write bench JSON: {e}"),
    }
}

//! SIMD kernel bench (PR 9): the portable 4-lane f64 microkernels behind
//! `Mat::matmul` / `Mat::gram` and the fused MGS prefix-error kernel
//! (`prefix_projection_errors`), priced at hot-path shapes so regressions
//! in the lane kernels — or in the thresholds routing around them — show
//! up as row-level diffs in `scripts/bench_compare.py`.  Parity with the
//! `*_naive` ground truth is asserted inline per shape, so a kernel that
//! silently drifts fails the bench (and the CI smoke run) rather than
//! polluting the JSON.
//!
//! Run: `cargo bench --bench simd_kernels` (or `scripts/bench.sh`).
//! `GRAFT_BENCH_SMOKE=1` shrinks shapes/reps to CI-smoke sizes.

mod bench_util;

use bench_util::{report, smoke_mode, time_it, JsonSink};
use graft::graft::prefix_projection_errors;
use graft::linalg::Mat;
use graft::rng::Rng;

fn randmat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn main() {
    let mut sink = JsonSink::new("simd_kernels");
    let (warm, reps) = if smoke_mode() { (1, 3) } else { (2, 10) };
    println!("== SIMD lane kernels ==\n");

    // -- matmul: square, tall-skinny, and panel shapes --------------------
    let mm_shapes: &[(usize, usize, usize)] = if smoke_mode() {
        &[(64, 64, 64), (128, 96, 32)]
    } else {
        &[(256, 256, 256), (512, 384, 128), (2048, 64, 64)]
    };
    for &(m, k, n) in mm_shapes {
        let a = randmat(m, k, 31);
        let b = randmat(k, n, 32);
        assert!(
            a.matmul(&b).sub(&a.matmul_naive(&b)).max_abs() < 1e-12,
            "matmul≡naive parity broke at {m}x{k}x{n}"
        );
        let t = time_it(warm, reps, || {
            bench_util::black_box(a.matmul(&b).max_abs());
        });
        report(&format!("matmul (M={m}, K={k}, N={n})"), t.0, t.1, t.2);
        sink.record("matmul_simd", &format!("M={m},K={k},N={n}"), t);
    }

    // -- gram: the symmetric half-work kernel -----------------------------
    let gram_shapes: &[(usize, usize)] =
        if smoke_mode() { &[(256, 32), (128, 96)] } else { &[(4096, 64), (1024, 256)] };
    for &(m, n) in gram_shapes {
        let a = randmat(m, n, 33);
        assert!(
            a.gram().sub(&a.gram_naive()).max_abs() < 1e-9,
            "gram≡naive parity broke at {m}x{n}"
        );
        let t = time_it(warm, reps, || {
            bench_util::black_box(a.gram().max_abs());
        });
        report(&format!("gram (M={m}, N={n})"), t.0, t.1, t.2);
        sink.record("gram_simd", &format!("M={m},N={n}"), t);
    }

    // -- fused MGS prefix errors: the rank-decision kernel ----------------
    let mgs_shapes: &[(usize, usize)] =
        if smoke_mode() { &[(32, 16), (64, 24)] } else { &[(64, 48), (256, 96)] };
    for &(e, r) in mgs_shapes {
        let gsel = randmat(e, r, 34);
        let mut rng = Rng::new(35);
        let gbar: Vec<f64> = (0..e).map(|_| rng.normal()).collect();
        let t = time_it(warm, reps, || {
            bench_util::black_box(prefix_projection_errors(&gsel, &gbar).len());
        });
        report(&format!("mgs prefix errors (E={e}, R={r})"), t.0, t.1, t.2);
        sink.record("mgs_simd", &format!("E={e},R={r}"), t);
    }

    match sink.write() {
        Ok(path) => println!("\nbench JSON → {}", path.display()),
        Err(e) => eprintln!("\nWARN could not write bench JSON: {e}"),
    }
}

//! Table 1 bench: selection-cost scaling per method.  The paper's claim:
//! GRAFT is O(KR² + |Rset|Rd) — linear in K, quadratic in R, independent
//! of n — while CRAIG/GradMatch/GLISTER scale with full gradient
//! comparisons and DRoP/SubSelNet are quadratic in n.
//!
//! Run: `cargo bench --bench table1_complexity`

mod bench_util;

use bench_util::{black_box, report, time_it};
use graft::linalg::{Mat, Workspace};
use graft::rng::Rng;
use graft::selection::{by_name, BatchView, Selector};

fn make_view(k: usize, r: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    Owned {
        features: Mat::from_fn(k, r, |_, _| rng.normal()),
        grads: Mat::from_fn(k, e, |_, _| rng.normal()),
        losses: (0..k).map(|_| rng.uniform()).collect(),
        labels: (0..k).map(|i| (i % classes) as i32).collect(),
        preds: (0..k).map(|i| (i % classes) as i32).collect(),
        classes,
        row_ids: (0..k).collect(),
    }
}

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

fn main() {
    println!("== Table 1: per-batch selection cost by method ==");
    let methods = [
        "maxvol", "cross-maxvol", "random", "craig", "gradmatch", "glister", "drop", "el2n",
    ];
    // One workspace + output buffer, as the trainer's refresh loop uses.
    let mut ws = Workspace::new();
    let mut out: Vec<usize> = Vec::new();
    // K scaling (R fixed): GRAFT-family should be ~linear, CRAIG ~quadratic.
    println!("\n-- scaling in K (R = 16, E = 64) --");
    for &k in &[64usize, 128, 256, 512] {
        let owned = make_view(k, 16, 64, 10, k as u64);
        for m in methods {
            let mut sel = by_name(m, 1).unwrap();
            let r = 16.min(k);
            let (mean, std, min) = time_it(2, 8, || {
                sel.select_into(&owned.view(), r, &mut ws, &mut out);
                black_box(out.len());
            });
            report(&format!("{m:<14} K={k:<5}"), mean, std, min);
        }
        println!();
    }
    // R scaling (K fixed): MaxVol quadratic in R by design.
    println!("-- scaling in R (K = 256, E = 64) --");
    for &r in &[4usize, 8, 16, 32, 64] {
        let owned = make_view(256, r.max(8), 64, 10, 7 + r as u64);
        for m in ["maxvol", "gradmatch", "craig"] {
            let mut sel = by_name(m, 1).unwrap();
            let (mean, std, min) = time_it(2, 8, || {
                sel.select_into(&owned.view(), r, &mut ws, &mut out);
                black_box(out.len());
            });
            report(&format!("{m:<14} R={r:<5}"), mean, std, min);
        }
        println!();
    }
    println!("(paper Table 1: GRAFT O(KR^2) linear in K; CRAIG/GradMatch linear in n\n with full gradient comparisons; DRoP quadratic in n — shapes above)");
}

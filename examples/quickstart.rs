//! Quickstart: train a classifier on the synthetic CIFAR-10 stand-in with
//! GRAFT subset selection at 25% data, and compare against full-data
//! training — accuracy, emissions, and steps.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use graft::runtime::{default_dir, Engine};
use graft::train::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::new(default_dir())?;

    let base = TrainConfig {
        dataset: "cifar10".into(),
        epochs: 20,
        ..TrainConfig::default()
    };

    println!("== full-data baseline ==");
    let full = train::run(&mut engine, &TrainConfig { method: "full".into(), ..base.clone() })?;
    println!("  {}", full.result.summary_row());

    println!("== GRAFT @ 25% ==");
    let graft = train::run(
        &mut engine,
        &TrainConfig { method: "graft".into(), fraction: 0.25, ..base.clone() },
    )?;
    println!("  {}", graft.result.summary_row());
    let (mu, sigma) = graft.alignment.mean_std();
    println!("  gradient alignment: mu={mu:.2} sigma={sigma:.2}");

    println!(
        "\nGRAFT kept {:.1}% of the accuracy at {:.0}% of the emissions",
        100.0 * graft.result.final_acc / full.result.final_acc,
        100.0 * graft.result.co2_kg / full.result.co2_kg,
    );
    Ok(())
}

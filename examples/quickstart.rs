//! Quickstart: the `SelectionEngine` facade in five minutes.
//!
//! Everything here runs offline — no PJRT artifacts required.  The demo
//! plants a batch whose gradients live in a low-rank subspace, then
//! drives GRAFT selection through every execution shape (serial, sharded,
//! pooled + overlap) with the SAME engine API, showing that the
//! dynamic-rank criterion survives each one.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! For the full paper pipeline (AOT train/select artifacts, energy
//! accounting, Tables/Figures) see `examples/e2e_train.rs` and the
//! `graft train` CLI — both sit on the same engine.

use graft::coordinator::SelectWindow;
use graft::engine::{EngineBuilder, ExecShape, FaultPolicy, RankMode};
use graft::linalg::Mat;
use graft::rng::Rng;

/// A K-row batch whose gradient sketches span a planted rank-3 subspace —
/// the geometry GRAFT's dynamic rank exploits.
fn planted_window(k: usize, seed: u64) -> SelectWindow {
    let (rc, e, p) = (16usize, 24usize, 3usize);
    let mut rng = Rng::new(seed);
    let loadings = Mat::from_fn(k, p, |_, _| rng.normal());
    let basis_f = Mat::from_fn(p, rc, |_, _| rng.normal());
    let basis_g = Mat::from_fn(p, e, |_, _| rng.normal());
    let mut features = loadings.matmul(&basis_f);
    let mut grads = loadings.matmul(&basis_g);
    for v in features.data_mut() {
        *v += 0.02 * rng.normal();
    }
    for v in grads.data_mut() {
        *v += 0.02 * rng.normal();
    }
    let labels: Vec<i32> = (0..k).map(|i| (i % 4) as i32).collect();
    SelectWindow {
        features,
        grads,
        losses: (0..k).map(|_| rng.uniform() * 2.0).collect(),
        preds: labels.clone(),
        labels,
        classes: 4,
        row_ids: (0..k).collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let k = 256;
    let win = planted_window(k, 7);
    let view = win.view();

    // -- 1. Strict budget, serial: take exactly f·K rows per batch -------
    let mut strict = EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .build()?;
    let sel = strict.select(&view)?;
    println!("strict @ 25%: kept {} of {k} rows (budget {})", sel.indices.len(), sel.budget);

    // -- 2. Adaptive rank: ε decides, the planted rank-3 geometry shows --
    let mut adaptive = EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .rank(RankMode::Adaptive { epsilon: 0.05 })
        .build()?;
    let sel = adaptive.select(&view)?;
    let d = sel.decision.expect("GRAFT reports its rank decision");
    println!(
        "adaptive ε=0.05: R* = {} (projection error {:.2e}, satisfied: {}) — \
         the planted rank-3 subspace needs far fewer than the {} -row budget",
        d.rank, d.error, d.satisfied, sel.budget
    );

    // -- 3. Same criterion, sharded: the gradient-aware merge + one rank
    //       authority keep ε/budget semantics fan-out-independent --------
    let mut sharded = EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .rank(RankMode::Adaptive { epsilon: 0.05 })
        .exec(ExecShape::Sharded { shards: 4 })
        .build()?;
    let sel = sharded.select(&view)?;
    let d = sel.decision.expect("the merge's rank authority decides");
    println!("sharded×4:      R* = {} (error {:.2e}) — same decision shape", d.rank, d.error);

    // -- 4. Streaming session on a persistent pool, overlapping window
    //       assembly with in-flight selection -----------------------------
    let mut pooled = EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .exec(ExecShape::Pooled { shards: 4, workers: 2, overlap: true })
        .build()?;
    let mut kept = 0usize;
    pooled.windows::<anyhow::Error, _, _>(
        8,
        |wi, _extractor| Ok(planted_window(k, 100 + wi as u64)),
        |_wi, _window, winners| kept += winners.len(),
    )?;
    println!(
        "pooled 4×2 + overlap: 8 windows streamed, {kept} rows kept \
         (assembly of window w+1 overlapped selection of window w)"
    );

    // -- 5. Fault tolerance: a poisoned batch is quarantined, and the
    //       subset records how it degraded instead of silently lying ------
    let mut hardened = EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .fault_policy(FaultPolicy::Degrade)
        .build()?;
    let mut poisoned = planted_window(k, 9);
    poisoned.features[(5, 0)] = f64::NAN;
    let sel = hardened.select(&poisoned.view())?;
    assert!(!sel.indices.contains(&5), "the quarantined row is never selected");
    for d in sel.degradations {
        println!("degrade policy:  {d}");
    }

    // -- 6. Misconfigurations fail with typed, field-naming errors --------
    let err = EngineBuilder::new()
        .overlap(true)
        .build()
        .err()
        .expect("overlap without a pool must be rejected");
    println!("typed validation: {err} (field = {})", err.field());

    Ok(())
}

//! Table 3 / Fig 4 scenario: feature-extractor ablation — SVD vs AE vs
//! ICA, as (a) a logistic-probe accuracy/time comparison (Table 3) and
//! (b) end-to-end GRAFT training with each extractor plus the FastMaxVol
//! vs CrossMaxVol sampler comparison (Fig 4).
//!
//! Run: `cargo run --release --example ablation_features [--epochs 10]`

use graft::config::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    graft::cmd::tables::table3(&args)?;
    graft::cmd::figures::fig4(&args)
}

//! Table 5 scenario: Fast MaxVol channel pruning — train a full model,
//! select the most informative 50% of hidden channels by MaxVol on the
//! activation matrix, and report params / accuracy / FLOPs / latency
//! before vs after (paper Table 5).
//!
//! Run: `cargo run --release --example channel_pruning`

use graft::config::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    graft::cmd::tables::table5(&args)
}

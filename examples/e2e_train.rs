//! End-to-end driver: exercises the FULL three-layer stack on real small
//! workloads, proving the layers compose —
//!
//!   L3 streaming coordinator (BatchProducer + RefreshScheduler +
//!   SubsetState)  →  PJRT runtime  →  L2 JAX model artifacts  →  L1
//!   Pallas Fast-MaxVol/projection kernels (inside `select`).
//!
//! Workload 1: synthetic CIFAR-10 (12.8k samples), GRAFT @25%, a few
//! hundred steps, loss curve logged.  Workload 2: the real Iris dataset.
//! Headline metric: Ψ(0.25) = acc@25% / acc@full (paper Fig 3 claims
//! Ψ > 0.8 at f = 0.25; recorded in EXPERIMENTS.md).
//!
//! Run: `cargo run --release --example e2e_train`

use graft::coordinator::{BatchProducer, RefreshScheduler, SubsetState};
use graft::data::loader::Batcher;
use graft::eval::report::save_result;
use graft::graft::BudgetedRankPolicy;
use graft::rng::Rng;
use graft::runtime::{default_dir, Engine, TrainState};
use graft::train::{self, energy::FlopModel, EnergyMeter, Schedule, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::new(default_dir())?;

    // ---------- Workload 1: synth CIFAR-10, hand-rolled pipeline ----------
    let config = "cifar10";
    let spec = engine.spec(config)?.clone();
    engine.warmup(config)?;
    let ds = train::load_dataset(config)?;
    let (trainset, test) = ds.split(0.8, 0x5917 ^ 42);
    let fraction = 0.25;
    let r_budget = ((fraction * spec.k as f64).round() as usize).clamp(1, spec.k);
    let epochs = 20usize;

    let mut state = TrainState::init(&spec, 42);
    let mut subset = SubsetState::full(trainset.n);
    let mut policy = BudgetedRankPolicy::strict(0.1);
    let mut meter = EnergyMeter::default();
    let flops = FlopModel::for_spec(&spec);
    let steps_per_epoch = ((trainset.n as f64 * fraction) as usize / spec.k).max(1);
    let mut scheduler = RefreshScheduler::every_epochs(5, steps_per_epoch);
    let sched = Schedule::Cosine { lr0: 0.1, lr_min: 0.001, total_steps: epochs * steps_per_epoch };
    let mut rng = Rng::new(7);
    let mut curve = String::from("step,loss,acc\n");

    let mut step = 0usize;
    for epoch in 0..epochs {
        // Stage 1 (Alg. 1): refresh S^t by scanning the train set — the
        // `select` artifact runs the L1 Pallas kernels per window.
        if scheduler.due(step) {
            scheduler.mark(step);
            let mut active = Vec::new();
            let mut order: Vec<usize> = (0..trainset.n).collect();
            rng.shuffle(&mut order);
            for win in order.chunks_exact(spec.k) {
                let (x, y) = (trainset.gather(win), trainset.one_hot(win));
                let out = engine.select(config, &state.params, &x, &y)?;
                meter.add_flops(flops.select_batch);
                let decision = policy.choose(&out.errors, r_budget, spec.rmax);
                let take = decision.rank.max(r_budget); // strict budget here
                for &bi in out.indices.iter().take(take.min(out.indices.len())) {
                    active.push(win[bi]);
                }
                if take > out.indices.len() {
                    let mut taken = vec![false; spec.k];
                    for &bi in &out.indices {
                        taken[bi] = true;
                    }
                    for bi in (0..spec.k).filter(|&i| !taken[i]).take(take - out.indices.len()) {
                        active.push(win[bi]);
                    }
                }
            }
            subset.refresh(active, epoch, trainset.n);
            println!(
                "[refresh] epoch {epoch}: |S^t| = {} ({:.0}% of train), generation {}",
                subset.len(),
                100.0 * subset.fraction(trainset.n),
                subset.generation
            );
        }

        // Stage 2: pipelined training over S^t — batch assembly overlaps
        // engine execution via the bounded-channel producer.
        let sub = trainset.subset("active", subset.rows());
        let bucket = spec.buckets.iter().copied().filter(|&b| b <= sub.n.min(spec.k)).max().unwrap();
        let mut producer = BatchProducer::spawn(sub, bucket, steps_per_epoch, 2, 42 ^ epoch as u64);
        while let Some(batch) = producer.next() {
            let lr = sched.at(step) as f32;
            let loss = engine.train_step(
                config, bucket, &mut state, &batch.x, &batch.y1h, &batch.w, lr, 0.9,
            )?;
            meter.add_flops(bucket as f64 * flops.train_per_sample);
            if step % 10 == 0 {
                println!("  step {step:>4}  epoch {epoch:>2}  loss {loss:.4}  lr {lr:.4}");
            }
            curve.push_str(&format!("{step},{loss:.6},\n"));
            step += 1;
        }
        let acc = train::evaluate(&mut engine, config, &spec, &state.params, &test, &mut meter, &flops)?;
        curve.push_str(&format!("{step},,{acc:.4}\n"));
        println!("  epoch {epoch:>2} done: test acc {:.2}%  co2 {:.6} kg", acc * 100.0, meter.co2_kg());
    }

    let acc_graft = train::evaluate(&mut engine, config, &spec, &state.params, &test, &mut meter, &flops)?;
    save_result("e2e_cifar10_curve.csv", &curve)?;

    // Full-data reference for the headline Ψ(0.25).
    println!("\n[reference] full-data run…");
    let full = train::run(
        &mut engine,
        &TrainConfig { dataset: config.into(), method: "full".into(), epochs, ..TrainConfig::default() },
    )?;
    let psi = acc_graft / full.result.final_acc;
    println!(
        "\nHEADLINE  Ψ(0.25) = {:.3}  (paper Fig 3: GRAFT keeps Ψ > 0.8 at f = 0.25)  — {}",
        psi,
        if psi > 0.8 { "REPRODUCED" } else { "NOT reproduced" }
    );

    // ---------- Workload 2: real Iris through the same stack ----------
    println!("\n[iris] same pipeline on Fisher's Iris…");
    let out = train::run(
        &mut engine,
        &TrainConfig {
            dataset: "iris".into(),
            method: "graft".into(),
            fraction: 0.5,
            epochs: 40,
            ..TrainConfig::default()
        },
    )?;
    println!("  {}", out.result.summary_row());

    println!("\nE2E driver complete; curves in results/e2e_cifar10_curve.csv");
    Ok(())
}

"""Golden-data generator: deterministic inputs + JAX outputs per config.

``make artifacts`` runs this after aot.py.  The Rust integration tests load
``artifacts/<config>/golden.bin``, execute the corresponding HLO artifacts
through PJRT, and assert the outputs match JAX bit-for-tolerance — the
cross-language numerics check for the whole AOT bridge.

Binary record format (little-endian), repeated until EOF:
    u32  name_len        | name bytes (utf-8)
    u8   dtype           | 0 = f32, 1 = i32
    u32  ndim            | ndim × u32 dims
    data (row-major)

Usage: python -m compile.golden [--out-dir ../artifacts] [--configs a,b]
"""

from __future__ import annotations

import argparse
import os
import struct
import sys

import jax.numpy as jnp
import numpy as np

from . import model
from .configs import CONFIGS


def write_record(f, name: str, arr: np.ndarray):
    arr = np.asarray(arr)
    shape = arr.shape  # before ascontiguousarray, which promotes 0-d to 1-d
    arr = np.ascontiguousarray(arr)
    if arr.dtype in (np.float32, np.float64):
        arr, code = arr.astype(np.float32), 0
    elif arr.dtype in (np.int32, np.int64):
        arr, code = arr.astype(np.int32), 1
    else:
        raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
    nb = name.encode()
    f.write(struct.pack("<I", len(nb)))
    f.write(nb)
    f.write(struct.pack("<BI", code, len(shape)))
    for dim in shape:
        f.write(struct.pack("<I", dim))
    f.write(arr.tobytes())


def golden_inputs(cfg: dict, seed: int = 7):
    rng = np.random.RandomState(seed)
    k, d, c = cfg["k"], cfg["d"], cfg["c"]
    params = model.init_params(d, cfg["h"], c, seed=seed + 1)
    x = rng.randn(k, d).astype(np.float32)
    y = rng.randint(0, c, size=k)
    y1h = np.eye(c, dtype=np.float32)[y]
    return params, x, y1h


def generate(name: str, cfg: dict, out_dir: str):
    params, x, y1h = golden_inputs(cfg)
    xj, yj = jnp.asarray(x), jnp.asarray(y1h)
    path = os.path.join(out_dir, name, "golden.bin")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        for pname, arr in zip(("w1", "b1", "w2", "b2"), params):
            write_record(f, f"in.{pname}", np.asarray(arr))
        write_record(f, "in.x", x)
        write_record(f, "in.y1h", y1h)

        v, g, losses, preds = model.embed(*params, xj, yj, rmax=cfg["rmax"])
        for n, a in (("v", v), ("g", g), ("losses", losses), ("preds", preds)):
            write_record(f, f"embed.{n}", np.asarray(a))

        p, d, gnorm, align = model.select(*params, xj, yj, rmax=cfg["rmax"])
        for n, a in (("p", p), ("d", d), ("gnorm", gnorm), ("align", align)):
            write_record(f, f"select.{n}", np.asarray(a))

        bucket = cfg["buckets"][min(2, len(cfg["buckets"]) - 1)]
        w = np.full((bucket,), 1.0 / bucket, np.float32)
        vel = tuple(jnp.zeros_like(t) for t in params)
        out = model.train_step(*params, *vel, xj[:bucket], yj[:bucket],
                               jnp.asarray(w), jnp.float32(0.05),
                               jnp.float32(0.9))
        write_record(f, "train.bucket", np.asarray(bucket, np.int32))
        names = ("w1", "b1", "w2", "b2", "v1", "v2", "v3", "v4", "loss")
        for n, a in zip(names, out):
            write_record(f, f"train.{n}", np.asarray(a))

        loss, correct = model.eval_step(*params, xj, yj)
        write_record(f, "eval.loss", np.asarray(loss))
        write_record(f, "eval.correct", np.asarray(correct))
    print(f"  golden {name}: {os.path.getsize(path)} bytes", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default=None)
    args = ap.parse_args(argv)
    names = list(CONFIGS) if args.configs is None else args.configs.split(",")
    out_dir = os.path.abspath(args.out_dir)
    for n in names:
        generate(n, CONFIGS[n], out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())

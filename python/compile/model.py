"""L2: JAX model + GRAFT compute graphs (build-time only; AOT → HLO text).

A 2-layer MLP classifier stands in for the paper's backbones (DESIGN.md §2):
GRAFT only interacts with a model through (a) batch feature matrices and
(b) per-sample gradient sketches, both of which the MLP exposes identically.

Portability constraint: the image's xla_extension 0.5.1 runtime has no
LAPACK FFI custom-calls, so every linear-algebra primitive here is plain
HLO — randomized subspace iteration for features, fori_loop MGS for
orthonormalisation (no jnp.linalg.svd/qr anywhere on the export path).

Exported computations per dataset config (see aot.py):

  embed(θ, X, Y1h)            → V(K×Rmax), Gemb(K×E), losses(K), preds(K)
  select(θ, X, Y1h)           → p(Rmax) i32, d(Rmax), gnorm(), align()
  train_step_b{B}(θ, v, X, Y1h, w, lr, mu) → θ', v', loss
  eval_step(θ, X, Y1h)        → loss(), ncorrect()

θ = (W1, b1, W2, b2); v = matching momentum buffers; w = per-row weights
(the masked-subset trick: fixed shapes + dynamic subset size, DESIGN.md §1).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fast_maxvol, prefix_projection_errors

_EPS = 1e-10
# Power-iteration sweeps for the feature subspace (q=2 is the classic
# Halko-Martinsson-Tropp recommendation for decaying spectra).
_POWER_ITERS = 2
_OMEGA_SEED = 0x5EED


class Params(NamedTuple):
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array


def init_params(d: int, h: int, c: int, seed: int = 0) -> Params:
    """He-initialised MLP parameters (numpy RNG → deterministic artifacts)."""
    rng = np.random.RandomState(seed)
    w1 = rng.randn(d, h).astype(np.float32) * np.sqrt(2.0 / d)
    w2 = rng.randn(h, c).astype(np.float32) * np.sqrt(2.0 / h)
    return Params(
        jnp.asarray(w1), jnp.zeros((h,), jnp.float32),
        jnp.asarray(w2), jnp.zeros((c,), jnp.float32),
    )


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def forward(params: Params, x: jax.Array):
    """Returns (logits, hidden activations, pre-activation)."""
    a1 = x @ params.w1 + params.b1
    h = jax.nn.relu(a1)
    logits = h @ params.w2 + params.b2
    return logits, h, a1


def per_sample_losses(params: Params, x: jax.Array, y1h: jax.Array) -> jax.Array:
    logits, _, _ = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(y1h * logp, axis=-1)


def weighted_loss(params: Params, x: jax.Array, y1h: jax.Array, w: jax.Array):
    """Σ_k w_k ℓ_k — the masked-subset objective (weights already 1/R*)."""
    return jnp.sum(per_sample_losses(params, x, y1h) * w)


# --------------------------------------------------------------------------
# Plain-HLO linear algebra
# --------------------------------------------------------------------------

def mgs(b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Modified Gram-Schmidt via fori_loop; returns (Q, column norms).

    Norms are captured *before* normalisation — after power iteration they
    estimate the singular-value ordering used to rank feature relevance
    (paper §3.1 Step 1: Rel(1) ≥ … ≥ Rel(R)).
    """
    k, r = b.shape

    def body(j, carry):
        q_all, norms = carry
        col = jax.lax.dynamic_slice_in_dim(q_all, j, 1, axis=1)[:, 0]

        def inner(i, acc):
            qi = jax.lax.dynamic_slice_in_dim(q_all, i, 1, axis=1)[:, 0]
            return acc - qi * jnp.dot(qi, acc)

        col = jax.lax.fori_loop(0, j, inner, col)
        nrm = jnp.sqrt(jnp.sum(col * col))
        qj = jnp.where(nrm > _EPS, col / jnp.maximum(nrm, _EPS),
                       jnp.zeros_like(col))
        q_all = jax.lax.dynamic_update_slice_in_dim(q_all, qj[:, None], j, axis=1)
        norms = jax.lax.dynamic_update_slice_in_dim(norms, nrm[None], j, axis=0)
        return q_all, norms

    return jax.lax.fori_loop(0, r, body, (b, jnp.zeros((r,), b.dtype)))


def subspace_features(x: jax.Array, rmax: int) -> jax.Array:
    """Importance-ordered low-rank feature matrix V = f(X) ∈ R^{K×Rmax}.

    Randomized subspace iteration (HMT 2011) with a *fixed* seeded Gaussian
    test matrix baked into the HLO as a constant: V spans the dominant
    left-singular subspace of the centered batch, with columns ordered by
    estimated singular value — exactly the "ordered extracted features" the
    Fast MaxVol sampler expects.
    """
    k, d = x.shape
    rng = np.random.RandomState(_OMEGA_SEED)
    omega = jnp.asarray(rng.randn(d, rmax).astype(np.float32))
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    b = xc @ omega
    for _ in range(_POWER_ITERS):
        q, _ = mgs(b)
        b = xc @ (xc.T @ q)
    q, norms = mgs(b)
    order = jnp.argsort(-norms)
    return jnp.take(q, order, axis=1)


# --------------------------------------------------------------------------
# Gradient sketches
# --------------------------------------------------------------------------

def grad_sketch(params: Params, x: jax.Array, y1h: jax.Array) -> jax.Array:
    """Per-sample gradient sketch Gemb ∈ R^{K×(C+H)} (analytic, no vmap).

    Concatenates the exact logit-gradient δ_k = p_k − y_k (the last-layer
    bias gradient) with the exact hidden-layer backprop signal
    (δ_k W2ᵀ) ⊙ relu'(a1) (the first-layer bias gradient).  This is the
    standard last-layer(s) gradient embedding used by GradMatch/BADGE-style
    methods; ⟨sketch_i, sketch_j⟩ approximates per-sample gradient inner
    products at ~1/d the cost of full gradients.
    """
    logits, h, a1 = forward(params, x)
    p = jax.nn.softmax(logits, axis=-1)
    delta = p - y1h                                  # (K, C)
    hidden = (delta @ params.w2.T) * (a1 > 0)        # (K, H)
    return jnp.concatenate([delta, hidden], axis=-1)


# --------------------------------------------------------------------------
# Exported computations
# --------------------------------------------------------------------------

def embed(w1, b1, w2, b2, x, y1h, *, rmax: int):
    """Batch embeddings for all selection methods (GRAFT + baselines)."""
    params = Params(w1, b1, w2, b2)
    v = subspace_features(x, rmax)
    g = grad_sketch(params, x, y1h)
    losses = per_sample_losses(params, x, y1h)
    logits, _, _ = forward(params, x)
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return v, g, losses, preds


def select(w1, b1, w2, b2, x, y1h, *, rmax: int):
    """GRAFT Stage-1: Fast MaxVol selection + prefix projection errors.

    Returns (p, d, gnorm, align):
      p     (Rmax,) int32  prefix-nested selected row indices
      d     (Rmax,)        normalised projection error per candidate rank
      gnorm ()             ‖ḡ‖₂ of the batch-mean gradient sketch
      align ()             cos(ḡ, mean of selected-at-Rmax sketches)
    """
    params = Params(w1, b1, w2, b2)
    v = subspace_features(x, rmax)
    p = fast_maxvol(v)                               # L1 Pallas kernel
    g = grad_sketch(params, x, y1h)                  # (K, E)
    gbar = jnp.mean(g, axis=0)                       # (E,)
    gsel = jnp.take(g, p, axis=0).T                  # (E, Rmax)
    d = prefix_projection_errors(gsel, gbar)         # L1 Pallas kernel
    gnorm = jnp.sqrt(jnp.sum(gbar * gbar))
    msel = jnp.mean(gsel, axis=1)
    align = jnp.dot(gbar, msel) / jnp.maximum(
        gnorm * jnp.sqrt(jnp.sum(msel * msel)), _EPS)
    return p, d, gnorm, align


def train_step(w1, b1, w2, b2, v1, v2, v3, v4, x, y1h, w, lr, mu):
    """One SGD+momentum step on the weighted (masked-subset) loss.

    Weights w encode the dynamic subset: w_k = 1/R* on selected rows, else 0
    (full-batch training = uniform 1/K).  lr/mu are runtime scalars so the
    Rust coordinator owns the cosine-annealing schedule.
    """
    params = Params(w1, b1, w2, b2)
    vel = Params(v1, v2, v3, v4)
    loss, grads = jax.value_and_grad(weighted_loss)(params, x, y1h, w)
    new_vel = jax.tree_util.tree_map(lambda v, g: mu * v + g, vel, grads)
    new_params = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, new_vel)
    return (*new_params, *new_vel, loss)


def eval_step(w1, b1, w2, b2, x, y1h):
    """Mean loss + per-sample correctness over one evaluation batch.

    Correctness is returned per row (not summed) so the Rust coordinator
    can mask wrap-padded tail rows exactly when the test set is not a
    multiple of K.
    """
    params = Params(w1, b1, w2, b2)
    losses = per_sample_losses(params, x, y1h)
    logits, _, _ = forward(params, x)
    correct = (jnp.argmax(logits, axis=-1) == jnp.argmax(y1h, axis=-1)
               ).astype(jnp.int32)
    return jnp.mean(losses), correct


# --------------------------------------------------------------------------
# Shape helpers for lowering (aot.py)
# --------------------------------------------------------------------------

def param_specs(d: int, h: int, c: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((d, h), f32), jax.ShapeDtypeStruct((h,), f32),
        jax.ShapeDtypeStruct((h, c), f32), jax.ShapeDtypeStruct((c,), f32),
    )


def batch_specs(k: int, d: int, c: int):
    f32 = jnp.float32
    return (jax.ShapeDtypeStruct((k, d), f32),
            jax.ShapeDtypeStruct((k, c), f32))


def lowerable(cfg: dict):
    """Yield (name, fn, arg_specs) for every artifact of one config."""
    d, c, h, k, rmax = cfg["d"], cfg["c"], cfg["h"], cfg["k"], cfg["rmax"]
    f32 = jnp.float32
    scalar = jax.ShapeDtypeStruct((), f32)
    p_specs = param_specs(d, h, c)

    yield ("embed", functools.partial(embed, rmax=rmax),
           (*p_specs, *batch_specs(k, d, c)))
    yield ("select", functools.partial(select, rmax=rmax),
           (*p_specs, *batch_specs(k, d, c)))
    for bucket in cfg["buckets"]:
        yield (f"train_step_b{bucket}", train_step,
               (*p_specs, *p_specs, *batch_specs(bucket, d, c),
                jax.ShapeDtypeStruct((bucket,), f32), scalar, scalar))
    yield ("eval_step", eval_step, (*p_specs, *batch_specs(k, d, c)))

"""Dataset/model configurations for artifact generation.

Each entry fixes the static shapes of one artifact family.  The Rust
coordinator reads the same values from ``artifacts/manifest.txt`` (flat
key-value format emitted by ``aot.py``) so both sides agree on shapes.

Fields
------
d      input feature dimension (flattened)
c      number of classes
h      hidden width of the 2-layer MLP classifier
k      mini-batch size K (rows fed to embed/select/eval)
rmax   maximum candidate rank / subset size per batch (Fast MaxVol depth)
buckets padded subset-size buckets for ``train_step`` artifacts; the
       coordinator rounds the dynamic R* up to the nearest bucket so the
       per-step compute actually shrinks with the subset (fixed-shape XLA).
"""

# Buckets are shared across configs (subset sizes as fractions of K=128-ish
# batches).  The largest bucket equals the batch size -> "full" training
# reuses the same artifact family.
DEFAULT_BUCKETS = [8, 16, 32, 64, 128]

CONFIGS = {
    # Synthetic stand-ins for the paper's image benchmarks (see DESIGN.md §2).
    "cifar10": dict(d=256, c=10, h=128, k=128, rmax=64, buckets=DEFAULT_BUCKETS),
    "cifar100": dict(d=256, c=100, h=128, k=128, rmax=64, buckets=DEFAULT_BUCKETS),
    "fashionmnist": dict(d=196, c=10, h=128, k=128, rmax=64, buckets=DEFAULT_BUCKETS),
    "tinyimagenet": dict(d=256, c=200, h=160, k=128, rmax=64, buckets=DEFAULT_BUCKETS),
    "caltech256": dict(d=256, c=257, h=160, k=128, rmax=64, buckets=DEFAULT_BUCKETS),
    "dermamnist": dict(d=147, c=7, h=96, k=128, rmax=64, buckets=DEFAULT_BUCKETS),
    # Synthetic IMDB: frozen text-embedding features + trainable head
    # (Table 2 scenario; K=100 matches the paper's fine-tuning batch size).
    "imdb": dict(d=128, c=2, h=64, k=100, rmax=50, buckets=[5, 10, 25, 50, 100]),
    # Iris is embedded verbatim on the Rust side (Table 4 scenario).
    "iris": dict(d=4, c=3, h=16, k=120, rmax=4, buckets=[2, 4, 8, 120]),
}


def grad_embed_dim(cfg: dict) -> int:
    """Dimension E of the per-sample gradient sketch (hidden + class)."""
    return cfg["h"] + cfg["c"]

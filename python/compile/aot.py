"""AOT export: lower every L2 computation to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
text with ``HloModuleProto::from_text_file`` and compiles on the PJRT CPU
client.  HLO text — NOT ``lowered.compile()``/``.serialize()`` — is the
interchange format: jax ≥ 0.5 serialises HloModuleProto with 64-bit
instruction ids, which xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Layout:
  artifacts/<config>/<name>.hlo.txt
  artifacts/manifest.txt      flat key-value file the Rust side parses
  artifacts/manifest.json     human-readable mirror

Usage:  python -m compile.aot [--out-dir ../artifacts] [--configs a,b,…]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, grad_embed_dim
from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser).

    Printed with ``print_large_constants=True``: the default printer elides
    constants above a size threshold as ``{...}``, which the xla_extension
    0.5.1 text parser silently materialises as ZEROS — baked constants
    (e.g. the subspace-iteration test matrix Ω) would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # 0.5.1's parser predates newer metadata attributes (source_end_line…);
    # metadata is debug-only, so drop it entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def export_config(name: str, cfg: dict, out_dir: str, verbose: bool = True):
    """Lower and write every artifact of one dataset config."""
    cfg_dir = os.path.join(out_dir, name)
    os.makedirs(cfg_dir, exist_ok=True)
    entries = []
    for art_name, fn, specs in model.lowerable(cfg):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(cfg_dir, f"{art_name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(art_name)
        if verbose:
            print(f"  {name}/{art_name}: {len(text)} chars "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return entries


def write_manifest(out_dir: str, exported: dict[str, list[str]]):
    """Flat key-value manifest (Rust parses this; JSON mirror for humans)."""
    lines = ["version 1"]
    for name, arts in exported.items():
        cfg = CONFIGS[name]
        lines.append(
            f"config {name} d {cfg['d']} c {cfg['c']} h {cfg['h']} "
            f"k {cfg['k']} rmax {cfg['rmax']} e {grad_embed_dim(cfg)} "
            f"buckets {','.join(str(b) for b in cfg['buckets'])} "
            f"artifacts {','.join(arts)}"
        )
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({n: {**CONFIGS[n], "e": grad_embed_dim(CONFIGS[n]),
                       "artifacts": a} for n, a in exported.items()},
                  f, indent=2)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default=None,
                    help="comma-separated subset of configs (default: all)")
    args = ap.parse_args(argv)

    names = list(CONFIGS) if args.configs is None else args.configs.split(",")
    unknown = [n for n in names if n not in CONFIGS]
    if unknown:
        print(f"unknown configs: {unknown}", file=sys.stderr)
        return 2

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    exported = {}
    for n in names:
        print(f"[aot] lowering config '{n}' …", flush=True)
        exported[n] = export_config(n, CONFIGS[n], out_dir)
    write_manifest(out_dir, exported)
    print(f"[aot] wrote {sum(len(v) for v in exported.values())} artifacts "
          f"for {len(exported)} configs in {time.time() - t0:.1f}s → {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Pure-numpy correctness oracles for the L1 Pallas kernels.

These deliberately use an *independent* formulation (explicit linear solves
and LAPACK QR/SVD) so a bug shared with the kernels cannot cancel out.
Test-time only — never lowered into HLO artifacts.
"""

from __future__ import annotations

import numpy as np


def fast_maxvol_ref(v: np.ndarray) -> np.ndarray:
    """Reference Fast MaxVol via explicit residual solves (paper §3.1).

    At step j the residual of column j against the previously selected rows
    is recomputed from scratch with a least-squares solve — O(KR³) total,
    but unambiguous.
    """
    v = np.asarray(v, dtype=np.float64)
    k, r = v.shape
    p: list[int] = []
    for j in range(r):
        col = v[:, j]
        if p:
            sub = v[np.array(p), :j]          # (j, j)
            rhs = v[np.array(p), j]           # (j,)
            coef, *_ = np.linalg.lstsq(sub, rhs, rcond=None)
            resid = col - v[:, :j] @ coef
        else:
            resid = col.copy()
        score = np.abs(resid)
        if p:
            score[np.array(p, dtype=int)] = -1.0  # enforce uniqueness
        p.append(int(np.argmax(score)))
    return np.asarray(p, dtype=np.int32)


def prefix_projection_ref(g: np.ndarray, gbar: np.ndarray) -> np.ndarray:
    """Reference prefix projection errors via LAPACK SVD.

    d_r = 1 − ‖Q_r^T ĝ‖² with Q_r a rank-aware orthonormal basis of the
    first r columns of g (zero/dependent columns contribute nothing).
    """
    g = np.asarray(g, dtype=np.float64)
    gbar = np.asarray(gbar, dtype=np.float64)
    e, r = g.shape
    nrm = np.linalg.norm(gbar)
    ghat = gbar / nrm if nrm > 1e-10 else np.zeros_like(gbar)
    out = np.empty(r)
    for j in range(1, r + 1):
        gj = g[:, :j]
        q, s, _ = np.linalg.svd(gj, full_matrices=False)
        rank = int(np.sum(s > s[0] * 1e-9)) if s.size and s[0] > 0 else 0
        q = q[:, :rank]
        cum = float(np.sum((q.T @ ghat) ** 2)) if rank else 0.0
        out[j - 1] = max(1.0 - cum, 0.0)
    return out


def log_volume(v: np.ndarray, rows, cols: int) -> float:
    """log |det V[rows[:cols], :cols]| — volume-monotonicity test helper."""
    sub = np.asarray(v, dtype=np.float64)[np.asarray(rows)[:cols], :cols]
    sign, logdet = np.linalg.slogdet(sub)
    return -np.inf if sign == 0 else float(logdet)

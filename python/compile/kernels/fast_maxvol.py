"""L1 Pallas kernel: Fast MaxVol row selection (paper §3.1, Step 2).

Given an importance-ordered feature matrix ``V ∈ R^{K×R}`` the kernel
greedily selects R row indices ``p = [p_1, …, p_R]`` such that each prefix
submatrix ``V[p[:j], :j]`` has (locally) maximal absolute determinant.  The
paper's key identity (Eq. 1 + Sylvester) reduces step ``j`` to

    p_j = argmax_i |r_j(i)|,
    r_j = v_j − V[:, :j−1] · V(p, :j−1)^{-1} · v_{p, j}

which we realise as one rank-1 Gaussian-elimination update per step —
``O(KR)`` per step, ``O(KR²)`` total, matching Table 1/Table 7.

TPU mapping (DESIGN.md §Hardware-Adaptation): the whole K×R tile is VMEM
resident (K=128, R=64 fp32 = 32 KiB), the per-step update is a rank-1
outer-product on the VPU, and the only sequential dependency is the scalar
argmax — no HBM traffic between steps.  On CPU we run ``interpret=True``.

The greedy sequence is *nested*: ``p[:r]`` is exactly the rank-r selection,
so one kernel invocation yields every candidate rank of the dynamic-rank
search (paper Alg. 1) for free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12


def _fast_maxvol_kernel(v_ref, p_ref, w_ref, m_ref):
    """Kernel body.

    v_ref : (K, R) input feature matrix (read-only)
    p_ref : (R,)   output selected row indices (int32)
    w_ref : (K, R) working residual matrix (output used as scratch)
    m_ref : (K,)   selected-row mask (output used as scratch; 1.0 = taken)
    """
    k, r = v_ref.shape
    w_ref[...] = v_ref[...]
    m_ref[...] = jnp.zeros((k,), v_ref.dtype)

    def body(j, _):
        w = w_ref[...]
        mask = m_ref[...]
        col = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)[:, 0]
        # Rows already selected have (numerically) zero residual; mask them
        # explicitly so rank-deficient inputs still yield unique indices.
        score = jnp.where(mask > 0.5, -1.0, jnp.abs(col))
        idx = jnp.argmax(score).astype(jnp.int32)
        piv = col[idx]
        safe = jnp.where(jnp.abs(piv) < _EPS,
                         jnp.where(piv >= 0, _EPS, -_EPS), piv)
        row = jax.lax.dynamic_slice_in_dim(w, idx, 1, axis=0)[0, :]
        # Rank-1 elimination: zeroes row `idx` in all later columns and the
        # selected rows stay zero by induction (paper Eq. 1).
        w_ref[...] = w - jnp.outer(col, row) / safe
        m_ref[...] = mask.at[idx].set(1.0)
        pl.store(p_ref, (pl.dslice(j, 1),), idx[None])
        return 0

    jax.lax.fori_loop(0, r, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fast_maxvol(v: jax.Array, interpret: bool = True) -> jax.Array:
    """Select ``R`` rows of ``v`` (K×R) by Fast MaxVol; returns int32 (R,).

    The returned index vector is prefix-nested: ``fast_maxvol(v)[:r]`` is the
    rank-r selection.
    """
    k, r = v.shape
    if r > k:
        raise ValueError(f"need R <= K, got K={k} R={r}")
    p, _, _ = pl.pallas_call(
        _fast_maxvol_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((k, r), v.dtype),
            jax.ShapeDtypeStruct((k,), v.dtype),
        ),
        interpret=interpret,
    )(v.astype(jnp.float32) if v.dtype == jnp.float64 else v)
    return p

"""L1 Pallas kernel: prefix projection errors for dynamic rank selection.

Paper §3.2: GRAFT picks the subset size R* minimising the projection error

    d_R = ‖ḡ − G̃_R G̃_R^T ḡ‖²  =  ‖ḡ‖² (1 − ‖G̃_R^T ĝ‖²)        (Lemma 1)

over candidate ranks.  Because Fast MaxVol selections are prefix-nested,
ONE modified-Gram-Schmidt sweep over the selected gradient matrix
``G ∈ R^{E×R}`` yields *every* prefix error: after orthonormalising column
``j`` against columns ``< j`` the cumulative alignment ``Σ_{i≤j} (q_i^T ĝ)²``
gives ``d_j = 1 − cum`` (normalised form, multiply by ‖ḡ‖² for Lemma 1's
absolute form).  Cost: O(E R²) — this is the ``O(|Rset|·R·d)`` sweep of
Table 7 collapsed into a single pass.

Numerical notes: two-pass MGS (re-orthogonalisation) for stability;
near-zero residual columns contribute 0 alignment instead of NaN, which is
exactly the right semantics for rank-deficient gradient subsets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-10


def _prefix_projection_kernel(g_ref, gbar_ref, d_ref, q_ref, c_ref):
    """Kernel body.

    g_ref    : (E, R) selected per-sample gradient sketches (columns)
    gbar_ref : (E,)   full-batch mean gradient sketch
    d_ref    : (R,)   output: normalised projection error per prefix rank
    q_ref    : (E, R) orthonormal basis (output used as scratch)
    c_ref    : (1,)   cumulative alignment carry (output used as scratch)
    """
    e, r = g_ref.shape
    gbar = gbar_ref[...]
    gnorm = jnp.sqrt(jnp.sum(gbar * gbar))
    ghat = jnp.where(gnorm > _EPS, gbar / jnp.maximum(gnorm, _EPS), 0.0)
    q_ref[...] = g_ref[...]
    c_ref[...] = jnp.zeros((1,), g_ref.dtype)

    def body(j, _):
        q_all = q_ref[...]
        q = jax.lax.dynamic_slice_in_dim(q_all, j, 1, axis=1)[:, 0]
        nrm0 = jnp.sqrt(jnp.sum(q * q))

        def ortho(col):
            def inner(i, acc):
                qi = jax.lax.dynamic_slice_in_dim(q_ref[...], i, 1, axis=1)[:, 0]
                return acc - qi * jnp.dot(qi, acc)

            return jax.lax.fori_loop(0, j, inner, col)

        # Two-pass MGS for stability against badly conditioned subsets.
        q = ortho(ortho(q))
        nrm = jnp.sqrt(jnp.sum(q * q))
        # Relative dependence test: an (almost) linearly dependent column
        # leaves only float cancellation noise — it must contribute nothing
        # rather than a spurious orthonormal direction.
        dependent = nrm <= jnp.maximum(1e-5 * nrm0, _EPS)
        q = jnp.where(dependent, jnp.zeros_like(q), q / jnp.maximum(nrm, _EPS))
        pl.store(q_ref, (slice(None), pl.dslice(j, 1)), q[:, None])

        a = jnp.dot(q, ghat)
        cum = c_ref[0] + a * a
        c_ref[...] = cum[None]
        d = jnp.maximum(1.0 - cum, 0.0)
        pl.store(d_ref, (pl.dslice(j, 1),), d[None])
        return 0

    jax.lax.fori_loop(0, r, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_projection_errors(
    g: jax.Array, gbar: jax.Array, interpret: bool = True
) -> jax.Array:
    """Normalised projection errors ``d_r = 1 − ‖Q_r^T ĝ‖²`` for r = 1..R.

    ``g`` is (E, R) with columns the selected samples' gradient sketches,
    ``gbar`` the (E,) batch-mean sketch.  Returns float (R,), monotonically
    non-increasing in r.
    """
    e, r = g.shape
    if gbar.shape != (e,):
        raise ValueError(f"gbar shape {gbar.shape} != ({e},)")
    dt = jnp.float32 if g.dtype == jnp.float64 else g.dtype
    d, _, _ = pl.pallas_call(
        _prefix_projection_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((r,), dt),
            jax.ShapeDtypeStruct((e, r), dt),
            jax.ShapeDtypeStruct((1,), dt),
        ),
        interpret=interpret,
    )(g.astype(dt), gbar.astype(dt))
    return d

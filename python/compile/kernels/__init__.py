"""L1 Pallas kernels (interpret-mode on CPU; see DESIGN.md §Hardware-Adaptation)."""

from .fast_maxvol import fast_maxvol
from .projection import prefix_projection_errors

__all__ = ["fast_maxvol", "prefix_projection_errors"]

"""AOT export tests: HLO text round-trip, manifest integrity, golden format."""

import io
import os
import struct

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, golden, model
from compile.configs import CONFIGS, grad_embed_dim


def test_to_hlo_text_roundtrips_smallest_config():
    cfg = CONFIGS["iris"]
    for name, fn, specs in model.lowerable(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # No LAPACK/FFI custom-calls may appear on the export path —
        # xla_extension 0.5.1 cannot execute them (DESIGN.md §1).
        assert "lapack" not in text.lower(), name
        assert "custom-call" not in text.lower(), name


def test_export_and_manifest(tmp_path):
    out = str(tmp_path)
    arts = aot.export_config("iris", CONFIGS["iris"], out, verbose=False)
    aot.write_manifest(out, {"iris": arts})
    assert (tmp_path / "iris" / "select.hlo.txt").exists()
    lines = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert lines[0] == "version 1"
    fields = lines[1].split()
    kv = dict(zip(fields[2::2], fields[3::2]))
    assert fields[0] == "config" and fields[1] == "iris"
    assert int(kv["d"]) == 4 and int(kv["rmax"]) == 4
    assert int(kv["e"]) == grad_embed_dim(CONFIGS["iris"])
    assert "select" in kv["artifacts"].split(",")


def _read_records(buf: bytes):
    f = io.BytesIO(buf)
    out = {}
    while True:
        head = f.read(4)
        if not head:
            break
        (nlen,) = struct.unpack("<I", head)
        name = f.read(nlen).decode()
        code, ndim = struct.unpack("<BI", f.read(5))
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
        dtype = np.float32 if code == 0 else np.int32
        n = int(np.prod(dims)) if dims else 1
        data = np.frombuffer(f.read(4 * n), dtype=dtype).reshape(dims)
        out[name] = data
    return out


def test_golden_roundtrip(tmp_path):
    golden.generate("iris", CONFIGS["iris"], str(tmp_path))
    recs = _read_records((tmp_path / "iris" / "golden.bin").read_bytes())
    cfg = CONFIGS["iris"]
    assert recs["in.x"].shape == (cfg["k"], cfg["d"])
    assert recs["select.p"].dtype == np.int32
    assert recs["select.p"].shape == (cfg["rmax"],)
    assert len(set(recs["select.p"].tolist())) == cfg["rmax"]
    # Golden outputs must agree with a fresh JAX evaluation (determinism).
    params, x, y1h = golden.golden_inputs(cfg)
    p, d, gnorm, align = model.select(*params, jnp.asarray(x),
                                      jnp.asarray(y1h), rmax=cfg["rmax"])
    np.testing.assert_array_equal(recs["select.p"], np.asarray(p))
    np.testing.assert_allclose(recs["select.d"], np.asarray(d), rtol=1e-6)
    assert recs["train.loss"].shape == ()
    assert np.isfinite(recs["train.loss"])


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_config_sanity(name):
    cfg = CONFIGS[name]
    assert cfg["rmax"] <= cfg["k"]
    assert cfg["rmax"] <= max(cfg["d"], cfg["rmax"])  # V is K×Rmax
    assert max(cfg["buckets"]) == cfg["k"], "largest bucket must be full batch"
    assert sorted(cfg["buckets"]) == cfg["buckets"]

"""L2 model tests: shapes for every config, gradient exactness, invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS, grad_embed_dim


def _batch(cfg, seed=0):
    rng = np.random.RandomState(seed)
    k, d, c = cfg["k"], cfg["d"], cfg["c"]
    x = jnp.asarray(rng.randn(k, d).astype(np.float32))
    y = rng.randint(0, c, size=k)
    y1h = jnp.asarray(np.eye(c, dtype=np.float32)[y])
    return x, y1h


@pytest.fixture(scope="module")
def small():
    cfg = CONFIGS["iris"]
    params = model.init_params(cfg["d"], cfg["h"], cfg["c"], seed=1)
    x, y1h = _batch(cfg, seed=2)
    return cfg, params, x, y1h


# ---------------------------------------------------------------------------
# Shapes (abstract eval — fast for every config)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_artifact_shapes(name):
    cfg = CONFIGS[name]
    k, rmax, e, c = cfg["k"], cfg["rmax"], grad_embed_dim(cfg), cfg["c"]
    for art, fn, specs in model.lowerable(cfg):
        out = jax.eval_shape(fn, *specs)
        if art == "embed":
            v, g, losses, preds = out
            assert v.shape == (k, rmax) and g.shape == (k, e)
            assert losses.shape == (k,) and preds.shape == (k,)
        elif art == "select":
            p, d, gnorm, align = out
            assert p.shape == (rmax,) and p.dtype == jnp.int32
            assert d.shape == (rmax,)
            assert gnorm.shape == () and align.shape == ()
        elif art.startswith("train_step_b"):
            b = int(art.split("_b")[1])
            assert b in cfg["buckets"]
            assert len(out) == 9  # 4 params + 4 velocities + loss
            assert out[-1].shape == ()
        elif art == "eval_step":
            loss, correct = out
            assert loss.shape == () and correct.shape == (k,)
            assert correct.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Gradient sketch exactness: the sketch IS the per-sample (b2, b1) gradient
# ---------------------------------------------------------------------------

def test_grad_sketch_is_exact_bias_gradient(small):
    cfg, params, x, y1h = small
    sketch = model.grad_sketch(params, x, y1h)
    c, h = cfg["c"], cfg["h"]

    def loss_one(p, xi, yi):
        return model.per_sample_losses(p, xi[None], yi[None])[0]

    grads = jax.vmap(lambda xi, yi: jax.grad(loss_one)(params, xi, yi))(x, y1h)
    np.testing.assert_allclose(sketch[:, :c], grads.b2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sketch[:, c:], grads.b1, rtol=1e-4, atol=1e-5)


def test_weighted_loss_grad_matches_subset_mean(small):
    """Masked-subset trick: weights 1/R on subset S == mean loss over S."""
    cfg, params, x, y1h = small
    k = cfg["k"]
    subset = np.array([3, 17, 42, 99])
    w = np.zeros(k, np.float32)
    w[subset] = 1.0 / len(subset)
    full = model.weighted_loss(params, x, y1h, jnp.asarray(w))
    direct = jnp.mean(model.per_sample_losses(
        params, x[subset], y1h[subset]))
    np.testing.assert_allclose(full, direct, rtol=1e-5)

    gfull = jax.grad(model.weighted_loss)(params, x, y1h, jnp.asarray(w))
    gdirect = jax.grad(
        lambda p: jnp.mean(model.per_sample_losses(p, x[subset], y1h[subset]))
    )(params)
    for a, b in zip(gfull, gdirect):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Subspace features
# ---------------------------------------------------------------------------

def test_subspace_features_orthonormal():
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(64, 32).astype(np.float32))
    v = model.subspace_features(x, 8)
    gram = np.asarray(v.T @ v)
    np.testing.assert_allclose(gram, np.eye(8), atol=1e-4)


def test_subspace_features_capture_dominant_subspace():
    """V must align with the true top left-singular subspace of Xc."""
    rng = np.random.RandomState(8)
    # Low-rank + noise: U (64×4) S V (4×32)
    u = rng.randn(64, 4)
    s = np.diag([50.0, 30.0, 20.0, 10.0])
    vt = rng.randn(4, 32)
    x = u @ s @ vt + 0.01 * rng.randn(64, 32)
    x = jnp.asarray(x.astype(np.float32))
    v = model.subspace_features(x, 4)
    xc = np.asarray(x) - np.asarray(x).mean(0)
    u_true, _, _ = np.linalg.svd(xc, full_matrices=False)
    u4 = u_true[:, :4]
    # Principal-angle energy: ‖U4ᵀ V‖_F² ≈ 4 when subspaces coincide.
    energy = np.linalg.norm(u4.T @ np.asarray(v)) ** 2
    assert energy > 3.9


def test_subspace_features_importance_ordered():
    rng = np.random.RandomState(9)
    u = rng.randn(96, 6)
    s = np.diag([100, 60, 30, 10, 4, 1.0])
    vt = rng.randn(6, 48)
    x = jnp.asarray((u @ s @ vt).astype(np.float32))
    v = model.subspace_features(x, 6)
    xc = np.asarray(x) - np.asarray(x).mean(0)
    # Rayleigh quotient per feature column should be (roughly) decreasing.
    energies = [float(np.linalg.norm(xc.T @ np.asarray(v)[:, j]))
                for j in range(6)]
    assert all(energies[i] >= energies[i + 1] * 0.9 for i in range(5)), energies


def test_mgs_reproduces_column_space():
    rng = np.random.RandomState(10)
    b = jnp.asarray(rng.randn(40, 6).astype(np.float32))
    q, norms = model.mgs(b)
    qn = np.asarray(q)
    np.testing.assert_allclose(qn.T @ qn, np.eye(6), atol=1e-4)
    # Q spans col(B): projecting B onto Q loses nothing.
    bn = np.asarray(b)
    np.testing.assert_allclose(qn @ (qn.T @ bn), bn, rtol=1e-3, atol=1e-3)
    assert float(norms[0]) > 0


# ---------------------------------------------------------------------------
# Training dynamics
# ---------------------------------------------------------------------------

def test_train_step_descends(small):
    cfg, params, x, y1h = small
    k = cfg["k"]
    w = jnp.full((k,), 1.0 / k, jnp.float32)
    vel = tuple(jnp.zeros_like(t) for t in params)
    cur, curv = params, vel
    losses = []
    for _ in range(30):
        out = model.train_step(*cur, *curv, x, y1h, w,
                               jnp.float32(0.5), jnp.float32(0.9))
        cur, curv, loss = out[:4], out[4:8], out[8]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_select_outputs_consistent(small):
    cfg, params, x, y1h = small
    p, d, gnorm, align = model.select(*params, x, y1h, rmax=cfg["rmax"])
    p = np.asarray(p)
    assert len(set(p.tolist())) == cfg["rmax"]
    dn = np.asarray(d)
    assert np.all(np.diff(dn) <= 1e-5)
    assert float(gnorm) > 0
    assert -1.0 - 1e-5 <= float(align) <= 1.0 + 1e-5


def test_eval_step_counts(small):
    cfg, params, x, y1h = small
    loss, correct = model.eval_step(*params, x, y1h)
    logits, _, _ = model.forward(params, x)
    want = (np.argmax(np.asarray(logits), -1)
            == np.argmax(np.asarray(y1h), -1)).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(correct), want)
    assert float(loss) > 0

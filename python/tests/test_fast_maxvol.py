"""L1 kernel tests: Fast MaxVol vs the numpy oracle + algebraic invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fast_maxvol
from compile.kernels.ref import fast_maxvol_ref, log_volume


def _rand(k, r, seed, dtype=np.float32, scale=1.0):
    rng = np.random.RandomState(seed)
    return (rng.randn(k, r) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Oracle agreement
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(4, 96),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference(k, r, seed):
    r = min(r, k)
    v = _rand(k, r, seed)
    got = np.asarray(fast_maxvol(v))
    want = fast_maxvol_ref(v)
    assert got.shape == (r,)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_matches_reference_scaled(seed, scale):
    v = _rand(48, 8, seed, scale=scale)
    np.testing.assert_array_equal(np.asarray(fast_maxvol(v)),
                                  fast_maxvol_ref(v))


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dtypes(dtype):
    v = _rand(32, 6, 7, dtype=dtype)
    got = np.asarray(fast_maxvol(v))
    np.testing.assert_array_equal(got, fast_maxvol_ref(v))


# ---------------------------------------------------------------------------
# Invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(k=st.integers(8, 64), r=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1))
def test_indices_unique_and_in_range(k, r, seed):
    r = min(r, k)
    p = np.asarray(fast_maxvol(_rand(k, r, seed)))
    assert len(set(p.tolist())) == r
    assert p.min() >= 0 and p.max() < k


def test_prefix_nested():
    """fast_maxvol(V)[:r] must equal fast_maxvol(V[:, :r]) — the nestedness
    that makes the one-pass dynamic-rank search valid."""
    v = _rand(64, 12, 123)
    full = np.asarray(fast_maxvol(v))
    for r in (1, 3, 6, 9):
        sub = np.asarray(fast_maxvol(v[:, :r]))
        np.testing.assert_array_equal(full[:r], sub)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_volume_beats_random(seed):
    """MaxVol's selected submatrix volume should beat a random selection
    (in expectation; we allow equality and compare against the median of
    several random draws to avoid flakiness)."""
    v = _rand(64, 8, seed)
    p = np.asarray(fast_maxvol(v))
    lv = log_volume(v, p, 8)
    rng = np.random.RandomState(seed ^ 0xABCDEF)
    rand_lvs = [
        log_volume(v, rng.permutation(64)[:8], 8) for _ in range(11)
    ]
    assert lv >= np.median(rand_lvs)


def test_first_index_is_max_abs_of_first_column():
    v = _rand(40, 5, 99)
    p = np.asarray(fast_maxvol(v))
    assert p[0] == np.argmax(np.abs(v[:, 0]))


def test_duplicate_rows_still_unique_selection():
    rng = np.random.RandomState(5)
    base = rng.randn(4, 6).astype(np.float32)
    v = np.vstack([base] * 8)  # 32 rows, only 4 distinct
    p = np.asarray(fast_maxvol(v))
    assert len(set(p.tolist())) == 6  # mask keeps selection unique


def test_rank_deficient_matrix():
    rng = np.random.RandomState(6)
    col = rng.randn(24, 1).astype(np.float32)
    v = np.hstack([col, 2 * col, -col, 0.5 * col])  # rank 1
    p = np.asarray(fast_maxvol(v))
    assert len(set(p.tolist())) == 4


def test_r_greater_than_k_raises():
    with pytest.raises(ValueError):
        fast_maxvol(np.zeros((3, 5), np.float32))


def test_identity_like_matrix():
    """On a permuted identity the selection must find the nonzero rows."""
    v = np.zeros((16, 4), np.float32)
    rows = [11, 2, 7, 14]
    for j, i in enumerate(rows):
        v[i, j] = 1.0 + j
    p = np.asarray(fast_maxvol(v))
    np.testing.assert_array_equal(p, rows)

"""L1 kernel tests: prefix projection errors vs the SVD oracle + invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import prefix_projection_errors
from compile.kernels.ref import prefix_projection_ref


def _case(e, r, seed, dtype=np.float32):
    rng = np.random.RandomState(seed)
    return (rng.randn(e, r).astype(dtype), rng.randn(e).astype(dtype))


@settings(max_examples=40, deadline=None)
@given(e=st.integers(2, 64), r=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_matches_reference(e, r, seed):
    g, gbar = _case(e, r, seed)
    got = np.asarray(prefix_projection_errors(g, gbar))
    want = prefix_projection_ref(g, gbar)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dtypes(dtype):
    g, gbar = _case(24, 6, 3, dtype)
    got = np.asarray(prefix_projection_errors(g, gbar))
    np.testing.assert_allclose(got, prefix_projection_ref(g, gbar),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(e=st.integers(4, 48), r=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1))
def test_monotone_nonincreasing_and_bounded(e, r, seed):
    g, gbar = _case(e, r, seed)
    d = np.asarray(prefix_projection_errors(g, gbar))
    assert np.all(d >= -1e-6) and np.all(d <= 1.0 + 1e-6)
    assert np.all(np.diff(d) <= 1e-5), "adding a basis vector cannot hurt"


def test_gbar_in_span_gives_zero_error():
    rng = np.random.RandomState(11)
    g = rng.randn(20, 5).astype(np.float32)
    gbar = (g @ rng.randn(5)).astype(np.float32)
    d = np.asarray(prefix_projection_errors(g, gbar))
    assert d[-1] < 1e-5


def test_orthogonal_gbar_gives_full_error():
    """ḡ orthogonal to every selected gradient → d_r = 1 for all r."""
    g = np.zeros((6, 3), np.float32)
    g[:3, 0] = [1, 0, 0]
    g[:3, 1] = [0, 1, 0]
    g[:3, 2] = [1, 1, 0]
    gbar = np.array([0, 0, 0, 0, 0, 1], np.float32)
    d = np.asarray(prefix_projection_errors(g, gbar))
    np.testing.assert_allclose(d, 1.0, atol=1e-6)


def test_zero_gbar_is_finite():
    g, _ = _case(16, 4, 2)
    d = np.asarray(prefix_projection_errors(g, np.zeros(16, np.float32)))
    assert np.all(np.isfinite(d))


def test_duplicate_columns_no_double_count():
    """A repeated column must not decrease the error twice."""
    rng = np.random.RandomState(13)
    col = rng.randn(12).astype(np.float32)
    g = np.stack([col, col, col], axis=1)
    gbar = rng.randn(12).astype(np.float32)
    d = np.asarray(prefix_projection_errors(g, gbar))
    np.testing.assert_allclose(d, d[0], atol=1e-5)
    np.testing.assert_allclose(
        d, prefix_projection_ref(g, gbar), rtol=2e-3, atol=2e-4)


def test_lemma1_consistency():
    """Lemma 1: ‖ḡ − Q Qᵀ ḡ‖² == ‖ḡ‖² · d_r (normalised error)."""
    rng = np.random.RandomState(17)
    g = rng.randn(30, 6).astype(np.float64)
    gbar = rng.randn(30).astype(np.float64)
    d = np.asarray(prefix_projection_errors(g, gbar))
    q, _, _ = np.linalg.svd(g, full_matrices=False)
    resid = gbar - q @ (q.T @ gbar)
    lhs = np.dot(resid, resid)
    rhs = np.dot(gbar, gbar) * d[-1]
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-6)


def test_bad_gbar_shape_raises():
    g, _ = _case(10, 3, 0)
    with pytest.raises(ValueError):
        prefix_projection_errors(g, np.zeros(11, np.float32))
